//! Persisted route observations — the sidecar JSONL that carries the
//! planner's measured per-route throughput EWMAs across process
//! restarts.
//!
//! [`crate::tuner::Planner::observe`] accumulates a decayed per-route
//! throughput signal from the adaptive backend's routed executions,
//! and [`Planner::rank`](crate::tuner::Planner::rank) blends it into
//! the calibrated scores so production drift can flip a dispatch
//! decision. Without persistence that drift signal dies with the
//! process and the next restart re-routes on the stale profile until
//! it re-learns the degradation. The sidecar closes the loop:
//!
//! * [`sidecar_path`] — the convention: observations live next to the
//!   calibration profile they amend (`calibration/baseline.jsonl` →
//!   `calibration/baseline.observed.jsonl`), so a profile and its
//!   drift history travel together.
//! * [`ObservedRoute`] — one route's decayed Mb/s, schema-tagged
//!   (`viterbi-observed/1`) line-delimited JSON like every other
//!   persisted record in this repo.
//! * Saving is **explicit** (`serve --save-observed`, or
//!   `DecodeServer::save_observed`): an automatic save-on-shutdown
//!   would write sidecars during every test run and silently couple
//!   runs to each other. Loading is automatic at planner
//!   construction ([`Planner::load`] /
//!   [`Planner::load_default`](crate::tuner::Planner::load_default))
//!   whenever the sidecar exists.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::json::{Json, ObjBuilder};

/// Schema tag stamped into every observed-route record.
pub const OBSERVED_SCHEMA_VERSION: &str = "viterbi-observed/1";

/// One persisted route observation: the decayed measured throughput of
/// a dispatch route at save time.
#[derive(Debug, Clone, PartialEq)]
pub struct ObservedRoute {
    /// Registry name of the routed engine.
    pub route: String,
    /// Decayed payload throughput, Mbit/s.
    pub mbps: f64,
}

impl ObservedRoute {
    /// Serialize to one JSON object (one sidecar line).
    pub fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("schema", OBSERVED_SCHEMA_VERSION)
            .str("route", &self.route)
            .num("mbps", self.mbps)
            .build()
    }

    /// Deserialize from a parsed JSON object, validating the schema
    /// tag and every field.
    pub fn from_json(j: &Json) -> Result<ObservedRoute, String> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing or non-string field \"schema\"".to_string())?;
        if schema != OBSERVED_SCHEMA_VERSION {
            return Err(format!(
                "unsupported schema {schema:?} (this harness reads {OBSERVED_SCHEMA_VERSION:?})"
            ));
        }
        let route = j
            .get("route")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| "missing or non-string field \"route\"".to_string())?;
        let mbps = j
            .get("mbps")
            .and_then(Json::as_f64)
            .ok_or_else(|| "missing or non-numeric field \"mbps\"".to_string())?;
        if !(mbps.is_finite() && mbps > 0.0) {
            return Err(format!("route {route:?} has a non-positive mbps ({mbps})"));
        }
        Ok(ObservedRoute { route, mbps })
    }
}

/// The sidecar path for a calibration profile:
/// `<dir>/<stem>.observed.jsonl` next to the profile file.
pub fn sidecar_path(profile: &Path) -> PathBuf {
    let stem = profile
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "profile".to_string());
    profile.with_file_name(format!("{stem}.observed.jsonl"))
}

/// The per-shard sidecar path for a gateway shard: shard 2 of
/// `calibration/baseline.observed.jsonl` writes
/// `calibration/baseline.observed.shard2.jsonl`, so N concurrent
/// shards never clobber one file.
pub fn shard_sidecar_path(base: &Path, shard: usize) -> PathBuf {
    let stem = base
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "observed".to_string());
    let ext = base.extension().map(|s| s.to_string_lossy().into_owned());
    let name = match ext {
        Some(ext) => format!("{stem}.shard{shard}.{ext}"),
        None => format!("{stem}.shard{shard}"),
    };
    base.with_file_name(name)
}

/// Read a sidecar together with any per-shard siblings
/// (`<stem>.shard<i>.jsonl`), merging duplicate routes by geometric
/// mean — the natural average for throughputs that the planner
/// compares by ratio. A missing base file with present shard files is
/// fine; so is the reverse; nothing present at all is `Ok(empty)`
/// (absence of drift history is the normal cold-start case, not an
/// error — only malformed files fail).
pub fn read_merged(base: &Path) -> Result<Vec<ObservedRoute>, String> {
    let mut sources: Vec<PathBuf> = Vec::new();
    if base.is_file() {
        sources.push(base.to_path_buf());
    }
    // Shard files are probed by index, not by directory scan: bounded,
    // deterministic order, and no dependence on readdir semantics.
    for shard in 0..64 {
        let p = shard_sidecar_path(base, shard);
        if p.is_file() {
            sources.push(p);
        }
    }
    // (sum of ln mbps, count) per route.
    let mut merged: Vec<(String, f64, usize)> = Vec::new();
    for path in &sources {
        for r in read_jsonl(path)? {
            match merged.iter_mut().find(|(name, _, _)| *name == r.route) {
                Some((_, ln_sum, n)) => {
                    *ln_sum += r.mbps.ln();
                    *n += 1;
                }
                None => merged.push((r.route, r.mbps.ln(), 1)),
            }
        }
    }
    Ok(merged
        .into_iter()
        .map(|(route, ln_sum, n)| ObservedRoute {
            route,
            mbps: (ln_sum / n as f64).exp(),
        })
        .collect())
}

/// Write route observations as line-delimited JSON (one per line).
pub fn write_jsonl(path: &Path, routes: &[ObservedRoute]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    for r in routes {
        writeln!(f, "{}", r.to_json().render())?;
    }
    Ok(())
}

/// Read a sidecar back. Blank lines are skipped; any malformed line
/// aborts with its line number.
pub fn read_jsonl(path: &Path) -> Result<Vec<ObservedRoute>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.push(
            ObservedRoute::from_json(&j).map_err(|e| format!("line {}: {e}", lineno + 1))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sidecar_sits_next_to_the_profile() {
        assert_eq!(
            sidecar_path(Path::new("calibration/baseline.jsonl")),
            PathBuf::from("calibration/baseline.observed.jsonl")
        );
        assert_eq!(
            sidecar_path(Path::new("profile.jsonl")),
            PathBuf::from("profile.observed.jsonl")
        );
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let r = ObservedRoute { route: "lanes-mt".into(), mbps: 312.5 };
        let back = ObservedRoute::from_json(&Json::parse(&r.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, r);
        let wrong =
            Json::parse(r#"{"schema":"viterbi-observed/9","route":"lanes","mbps":1.0}"#).unwrap();
        assert!(ObservedRoute::from_json(&wrong).unwrap_err().contains("unsupported schema"));
        let bad =
            Json::parse(r#"{"schema":"viterbi-observed/1","route":"lanes","mbps":0.0}"#).unwrap();
        assert!(ObservedRoute::from_json(&bad).unwrap_err().contains("non-positive"));
    }

    #[test]
    fn shard_sidecar_naming() {
        assert_eq!(
            shard_sidecar_path(Path::new("calibration/baseline.observed.jsonl"), 2),
            PathBuf::from("calibration/baseline.observed.shard2.jsonl")
        );
        assert_eq!(
            shard_sidecar_path(Path::new("obs"), 0),
            PathBuf::from("obs.shard0")
        );
    }

    #[test]
    fn read_merged_combines_base_and_shards_by_geometric_mean() {
        let dir = std::env::temp_dir().join(format!("OBSERVED_merge_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("prof.observed.jsonl");
        write_jsonl(&base, &[ObservedRoute { route: "lanes".into(), mbps: 100.0 }]).unwrap();
        write_jsonl(
            &shard_sidecar_path(&base, 0),
            &[
                ObservedRoute { route: "lanes".into(), mbps: 400.0 },
                ObservedRoute { route: "parallel".into(), mbps: 50.0 },
            ],
        )
        .unwrap();
        write_jsonl(
            &shard_sidecar_path(&base, 3),
            &[ObservedRoute { route: "lanes".into(), mbps: 200.0 }],
        )
        .unwrap();
        let merged = read_merged(&base).unwrap();
        let lanes = merged.iter().find(|r| r.route == "lanes").unwrap();
        // Geometric mean of 100, 400, 200 = (100·400·200)^(1/3) = 200.
        assert!((lanes.mbps - 200.0).abs() < 1e-9, "got {}", lanes.mbps);
        let par = merged.iter().find(|r| r.route == "parallel").unwrap();
        assert!((par.mbps - 50.0).abs() < 1e-9);
        // Shard files alone (no base) still load.
        std::fs::remove_file(&base).unwrap();
        let merged = read_merged(&base).unwrap();
        assert_eq!(merged.len(), 2);
        // Nothing present at all is the cold-start case: Ok(empty).
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(read_merged(&base).unwrap(), Vec::new());
    }

    #[test]
    fn jsonl_file_roundtrip() {
        let routes = vec![
            ObservedRoute { route: "lanes".into(), mbps: 400.0 },
            ObservedRoute { route: "parallel".into(), mbps: 180.25 },
        ];
        let dir = std::env::temp_dir();
        let path = dir.join(format!("OBSERVED_test_{}.jsonl", std::process::id()));
        write_jsonl(&path, &routes).unwrap();
        assert_eq!(read_jsonl(&path).unwrap(), routes);
        let _ = std::fs::remove_file(&path);
    }
}
