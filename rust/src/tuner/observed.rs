//! Persisted route observations — the sidecar JSONL that carries the
//! planner's measured per-route throughput EWMAs across process
//! restarts.
//!
//! [`crate::tuner::Planner::observe`] accumulates a decayed per-route
//! throughput signal from the adaptive backend's routed executions,
//! and [`Planner::rank`](crate::tuner::Planner::rank) blends it into
//! the calibrated scores so production drift can flip a dispatch
//! decision. Without persistence that drift signal dies with the
//! process and the next restart re-routes on the stale profile until
//! it re-learns the degradation. The sidecar closes the loop:
//!
//! * [`sidecar_path`] — the convention: observations live next to the
//!   calibration profile they amend (`calibration/baseline.jsonl` →
//!   `calibration/baseline.observed.jsonl`), so a profile and its
//!   drift history travel together.
//! * [`ObservedRoute`] — one route's decayed Mb/s, schema-tagged
//!   (`viterbi-observed/1`) line-delimited JSON like every other
//!   persisted record in this repo.
//! * Saving is **explicit** (`serve --save-observed`, or
//!   `DecodeServer::save_observed`): an automatic save-on-shutdown
//!   would write sidecars during every test run and silently couple
//!   runs to each other. Loading is automatic at planner
//!   construction ([`Planner::load`] /
//!   [`Planner::load_default`](crate::tuner::Planner::load_default))
//!   whenever the sidecar exists.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::json::{Json, ObjBuilder};

/// Schema tag stamped into every observed-route record.
pub const OBSERVED_SCHEMA_VERSION: &str = "viterbi-observed/1";

/// One persisted route observation: the decayed measured throughput of
/// a dispatch route at save time.
#[derive(Debug, Clone, PartialEq)]
pub struct ObservedRoute {
    /// Registry name of the routed engine.
    pub route: String,
    /// Decayed payload throughput, Mbit/s.
    pub mbps: f64,
}

impl ObservedRoute {
    /// Serialize to one JSON object (one sidecar line).
    pub fn to_json(&self) -> Json {
        ObjBuilder::new()
            .str("schema", OBSERVED_SCHEMA_VERSION)
            .str("route", &self.route)
            .num("mbps", self.mbps)
            .build()
    }

    /// Deserialize from a parsed JSON object, validating the schema
    /// tag and every field.
    pub fn from_json(j: &Json) -> Result<ObservedRoute, String> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing or non-string field \"schema\"".to_string())?;
        if schema != OBSERVED_SCHEMA_VERSION {
            return Err(format!(
                "unsupported schema {schema:?} (this harness reads {OBSERVED_SCHEMA_VERSION:?})"
            ));
        }
        let route = j
            .get("route")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| "missing or non-string field \"route\"".to_string())?;
        let mbps = j
            .get("mbps")
            .and_then(Json::as_f64)
            .ok_or_else(|| "missing or non-numeric field \"mbps\"".to_string())?;
        if !(mbps.is_finite() && mbps > 0.0) {
            return Err(format!("route {route:?} has a non-positive mbps ({mbps})"));
        }
        Ok(ObservedRoute { route, mbps })
    }
}

/// The sidecar path for a calibration profile:
/// `<dir>/<stem>.observed.jsonl` next to the profile file.
pub fn sidecar_path(profile: &Path) -> PathBuf {
    let stem = profile
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "profile".to_string());
    profile.with_file_name(format!("{stem}.observed.jsonl"))
}

/// Write route observations as line-delimited JSON (one per line).
pub fn write_jsonl(path: &Path, routes: &[ObservedRoute]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    for r in routes {
        writeln!(f, "{}", r.to_json().render())?;
    }
    Ok(())
}

/// Read a sidecar back. Blank lines are skipped; any malformed line
/// aborts with its line number.
pub fn read_jsonl(path: &Path) -> Result<Vec<ObservedRoute>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.push(
            ObservedRoute::from_json(&j).map_err(|e| format!("line {}: {e}", lineno + 1))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sidecar_sits_next_to_the_profile() {
        assert_eq!(
            sidecar_path(Path::new("calibration/baseline.jsonl")),
            PathBuf::from("calibration/baseline.observed.jsonl")
        );
        assert_eq!(
            sidecar_path(Path::new("profile.jsonl")),
            PathBuf::from("profile.observed.jsonl")
        );
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let r = ObservedRoute { route: "lanes-mt".into(), mbps: 312.5 };
        let back = ObservedRoute::from_json(&Json::parse(&r.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, r);
        let wrong =
            Json::parse(r#"{"schema":"viterbi-observed/9","route":"lanes","mbps":1.0}"#).unwrap();
        assert!(ObservedRoute::from_json(&wrong).unwrap_err().contains("unsupported schema"));
        let bad =
            Json::parse(r#"{"schema":"viterbi-observed/1","route":"lanes","mbps":0.0}"#).unwrap();
        assert!(ObservedRoute::from_json(&bad).unwrap_err().contains("non-positive"));
    }

    #[test]
    fn jsonl_file_roundtrip() {
        let routes = vec![
            ObservedRoute { route: "lanes".into(), mbps: 400.0 },
            ObservedRoute { route: "parallel".into(), mbps: 180.25 },
        ];
        let dir = std::env::temp_dir();
        let path = dir.join(format!("OBSERVED_test_{}.jsonl", std::process::id()));
        write_jsonl(&path, &routes).unwrap();
        assert_eq!(read_jsonl(&path).unwrap(), routes);
        let _ = std::fs::remove_file(&path);
    }
}
