//! Calibration-driven adaptive engine dispatch — the tuner turns the
//! bench corpus from a reporting artifact into the serving control
//! plane.
//!
//! The paper's central observation is that the best decode strategy
//! depends on workload geometry (frame length, constraint length K,
//! batch width): its unified kernel wins at the paper's operating
//! point but the crossover against block-based and frame-parallel
//! baselines moves with the shape. This module makes that decision
//! automatic, in three pieces:
//!
//! * [`calibrate`] — a calibration runner that sweeps the dispatch
//!   candidates over a (K × frame length × batch width) grid with the
//!   existing `bench` machinery and persists a versioned
//!   [`CalibrationProfile`] JSONL file (`viterbi-tune/1`), each cell
//!   carrying the `memmodel` working-set estimate;
//! * [`planner`] — [`Planner`] loads a profile, interpolates to the
//!   nearest measured cell, and returns a ranked engine choice for a
//!   job geometry under a memory budget, with a static heuristic
//!   fallback when no profile exists;
//! * [`auto`] — the `auto` registry engine wrapping the planner behind
//!   the shared `Engine` interface; the coordinator's
//!   `BackendSpec::Auto` routes every dynamic batch through the same
//!   planner (uniform lane-groupable batches to the lane engines,
//!   ragged ones to `parallel`/`unified`);
//! * [`observed`] — the persisted drift signal: the planner's measured
//!   per-route throughput EWMAs save to an `*.observed.jsonl` sidecar
//!   next to the profile (explicitly — `serve --save-observed` or
//!   `DecodeServer::save_observed`) and reload at planner
//!   construction, so drift-driven route flips survive restarts.
//!
//! All dispatch candidates decode bit-exactly identically, so routing
//! is a pure performance decision; `rust/tests/tuner_props.rs` pins
//! `auto` against `unified` and property-tests the planner's registry
//! and budget invariants.

#![warn(missing_docs)]

pub mod auto;
pub mod calibrate;
pub mod observed;
pub mod planner;
pub mod profile;

pub use auto::AutoEngine;
pub use calibrate::{run_calibration, CalibrationGrid};
pub use observed::{
    read_merged, shard_sidecar_path, sidecar_path, ObservedRoute, OBSERVED_SCHEMA_VERSION,
};
pub use planner::{
    host_name, parse_batches, parse_ks, Choice, JobShape, Planner, PlannerConfig,
    BLOCKS_STREAM_MIN, BUDGET_ENV, DEFAULT_BUDGET_BYTES, DISPATCH_CANDIDATES, LANE_BATCH_MIN,
    PROFILE_ENV, TGEMM_K_MIN,
};
pub use profile::{CalibrationProfile, CalibrationRecord, TUNE_SCHEMA_VERSION};
