//! Analytic GPU memory & occupancy model.
//!
//! The paper's core argument is *memory-driven*: the unified kernel
//! keeps all intermediate data (branch metrics, path metrics, survivor
//! paths) in shared memory, so (i) global-memory traffic for survivors
//! disappears (Table I) and (ii) throughput becomes a function of how
//! many blocks fit per SM given their shared-memory footprint. This
//! module reproduces that arithmetic with V100 parameters, yielding
//! Table I and the predicted *shape* of Tables IV/V on the paper's own
//! hardware — our measured CPU numbers are reported next to these
//! predictions in EXPERIMENTS.md.

pub mod occupancy;
pub mod smem;

pub use occupancy::{GpuParams, OccupancyModel, ThroughputEstimate};
pub use smem::{
    global_memory_table, lane_traceback_working_bytes, sova_margin_bytes, tgemm_slab_bytes,
    tgemm_stage_batch, tgemm_tile_states, traceback_working_bytes, FootprintBreakdown, Method,
    SmemLayout,
};
