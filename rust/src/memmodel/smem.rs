//! Shared-memory footprint accounting (paper §IV-B/C/F) and the
//! global-memory comparison of Table I.
//!
//! All sizes are in bytes for one frame-processing block, for a code
//! with `s = 2^{k−1}` states and β output lanes, frame geometry
//! (f, v1, v2) and parallel-traceback subframe size f0.

use crate::frames::plan::FrameGeometry;

/// The three method families compared in Table I.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Refs [2]-[3]: one frame = whole stream, serial traceback.
    WholeStream,
    /// Refs [4]-[10]: tiled frames, survivors in global memory,
    /// serial per-frame traceback.
    TiledGlobal,
    /// The paper: unified kernel, survivors in shared memory,
    /// parallel traceback.
    Unified,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::WholeStream => "(a) refs [2]-[3]",
            Method::TiledGlobal => "(b) refs [4]-[10]",
            Method::Unified => "(c) proposed",
        }
    }
}

/// Byte-level breakdown of one block's shared-memory footprint under
/// the paper's §IV-B/§IV-C/§IV-F optimizations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FootprintBreakdown {
    /// De-punctured LLR frame: β · span · 4 B (f32).
    pub llr_bytes: usize,
    /// Branch metrics after the repetitive-pattern + complement-halving
    /// optimizations: 2^{β−1} · S · 4 B with stage sub-folding factor S
    /// (S = span when not folded).
    pub branch_metric_bytes: usize,
    /// Path metrics: two ping-pong rows of s f32 (σ needs only the
    /// previous stage, §IV-C).
    pub path_metric_bytes: usize,
    /// Survivor decisions: 1 bit per state per stage, bit-packed.
    pub survivor_bytes: usize,
    /// Parallel-traceback boundary states: one u32 per subframe.
    pub boundary_bytes: usize,
}

impl FootprintBreakdown {
    pub fn total(&self) -> usize {
        self.llr_bytes
            + self.branch_metric_bytes
            + self.path_metric_bytes
            + self.survivor_bytes
            + self.boundary_bytes
    }
}

/// Shared-memory layout calculator for one frame block.
#[derive(Debug, Clone, Copy)]
pub struct SmemLayout {
    pub k: u32,
    pub beta: u32,
    pub geo: FrameGeometry,
    /// Subframe size for parallel traceback (None = serial traceback).
    pub f0: Option<usize>,
    /// Warp-efficient sub-folding factor S (§IV-B): branch metrics are
    /// produced and consumed in S-stage slices instead of all at once.
    pub fold_stages: Option<usize>,
    /// Array-lifetime reuse (§IV-F): overlap the de-punctured-frame
    /// array with the survivor array, and boundary states with PM.
    pub reuse_arrays: bool,
}

impl SmemLayout {
    pub fn states(&self) -> usize {
        1usize << (self.k - 1)
    }

    pub fn span(&self) -> usize {
        self.geo.span()
    }

    /// Naive footprint (paper eq. 6 for branch metrics; full survivor
    /// and PM matrices, no optimizations) — the strawman.
    pub fn naive(&self) -> FootprintBreakdown {
        let s = self.states();
        let span = self.span();
        FootprintBreakdown {
            llr_bytes: self.beta as usize * span * 4,
            // eq. (6): 2^k × span entries (both branches per state).
            branch_metric_bytes: 2 * s * span * 4,
            path_metric_bytes: s * span * 4,
            // one byte per (state, stage) predecessor index.
            survivor_bytes: s * span,
            boundary_bytes: 0,
        }
    }

    /// Optimized footprint with the paper's §IV-B/C/F strategies plus
    /// our bit-packed survivors (the Pallas kernel's layout).
    pub fn optimized(&self) -> FootprintBreakdown {
        let s = self.states();
        let span = self.span();
        let fold = self.fold_stages.unwrap_or(span).min(span);
        // eq. (9): 2^{β−1} unique metrics per stage, folded to S stages.
        let branch_metric_bytes = (1usize << (self.beta - 1)) * fold * 4;
        let path_metric_bytes = 2 * s * 4; // ping-pong rows (§IV-C)
        let survivor_bytes = (s + 7) / 8 * span; // 1 bit/state/stage
        let n_sub = match self.f0 {
            Some(f0) => (self.geo.f + f0 - 1) / f0,
            None => 0,
        };
        let llr_bytes = self.beta as usize * span * 4;
        let boundary_bytes = n_sub * 4;
        let mut b = FootprintBreakdown {
            llr_bytes,
            branch_metric_bytes,
            path_metric_bytes,
            survivor_bytes,
            boundary_bytes,
        };
        if self.reuse_arrays {
            // §IV-F: survivor array shares storage with the de-punctured
            // frame (their lifetimes are disjoint: the frame is consumed
            // as survivors are produced, stage by stage, within a fold
            // slice), and boundary states share with a PM row.
            let shared = b.llr_bytes.max(b.survivor_bytes);
            b.survivor_bytes = shared;
            b.llr_bytes = 0;
            b.boundary_bytes = 0; // folded into PM row slack
        }
        b
    }
}

/// Peak resident traceback working memory, in bytes, for a decoder
/// that keeps `stages` stages of bit-packed survivor decisions plus
/// two ping-pong path-metric rows live — the CPU analogue of the
/// paper's shared-memory survivor budget, and the number the benchmark
/// subsystem records as `peak_traceback_bytes` (BENCHMARKS.md).
///
/// For whole-stream decoders `stages` is the stream length; for the
/// tiled/unified engines it is the frame span (v1 + f + v2); for the
/// streaming decoder it is the decision delay window.
pub fn traceback_working_bytes(states: usize, stages: usize) -> usize {
    let words_per_stage = (states + 63) / 64;
    words_per_stage * 8 * stages + 2 * states * 4
}

/// Additional resident working memory a SOVA (soft-output) decode
/// carries on top of [`traceback_working_bytes`]: the competitor
/// sweep's Δ margins cost one f32 per state per stage — **4
/// bytes/state/stage** — because unlike the 1-bit survivor decisions,
/// margins cannot be bit-packed. This is the registry's
/// `soft_margin_bytes` rule, so the planner's budget clamp sees the
/// true soft-request working set (ROADMAP: the gap the hard-only
/// `traceback_bytes` rule left).
pub fn sova_margin_bytes(states: usize, stages: usize) -> usize {
    4 * states * stages
}

/// L1 working-set budget for one tgemm state tile, in bytes. Half a
/// typical 32 KiB L1d: the tile's streams (previous path-metric pair,
/// slab metrics, output row, sign-difference buffers) should co-reside
/// with the stack and the decision words without evicting each other.
pub const TGEMM_L1_TILE_BUDGET: usize = 16 * 1024;

/// Bytes one butterfly index `j` touches per tgemm tile pass: two
/// previous-row f32, two slab f32, two output f32 (lo/hi halves) and
/// two sign-difference f32 — 8 × 4 B.
pub const TGEMM_TILE_BYTES_PER_INDEX: usize = 32;

/// L2 budget for the tgemm stage-batched branch-metric slab, in bytes
/// (a conservative slice of a per-core L2, leaving room for the
/// survivor words streaming through).
pub const TGEMM_L2_SLAB_BUDGET: usize = 256 * 1024;

/// Butterfly indices per tgemm state tile: as many `j` as fit the L1
/// tile budget, clamped to the half-trellis. K ≤ 11 fits in one tile;
/// larger codes split so each pass stays L1-resident.
pub fn tgemm_tile_states(states: usize) -> usize {
    let half = (states / 2).max(1);
    (TGEMM_L1_TILE_BUDGET / TGEMM_TILE_BYTES_PER_INDEX).min(half).max(1)
}

/// Stages per tgemm branch-metric slab: as many as keep the slab
/// (`batch · states` f32) inside the L2 budget, clamped to 4..=64 so
/// tiny codes do not batch absurdly and huge codes still amortize the
/// per-batch sweep setup.
pub fn tgemm_stage_batch(states: usize) -> usize {
    (TGEMM_L2_SLAB_BUDGET / (states.max(1) * 4)).clamp(4, 64)
}

/// Resident bytes of the tgemm branch-metric slab at the calibrated
/// batch — the term the registry's `traceback_bytes` rule adds on top
/// of the whole-stream survivor storage.
pub fn tgemm_slab_bytes(states: usize) -> usize {
    tgemm_stage_batch(states) * states * 4
}

/// Peak resident traceback working memory for one **lane group** of
/// the lane-batched engines (`crate::lanes`): survivor decisions are
/// packed one bit per state per stage **per lane** into `u64` words
/// (one word per (stage, state) for up to 64 lanes), plus two
/// lane-major ping-pong path-metric slabs of `states · lanes` f32.
///
/// At `lanes = 64` the survivor term is exactly
/// `states · stages · lanes / 8` bytes — 1 bit per decision, the same
/// density the paper's shared-memory survivor layout achieves per
/// frame, with zero per-frame padding.
pub fn lane_traceback_working_bytes(states: usize, stages: usize, lanes: usize) -> usize {
    let words_per_state = (lanes + 63) / 64;
    states * stages * 8 * words_per_state + 2 * states * lanes * 4
}

/// Global-memory usage for intermediate (survivor) data per Table I,
/// in *entries* as the paper states them (O-notation made concrete).
///
/// Returns (frames, frame_size_stages, parallelism_pm, parallelism_tb,
/// global_entries) for a stream of `n` stages.
pub fn global_memory_table(
    method: Method,
    k: u32,
    n: usize,
    geo: FrameGeometry,
    f0: Option<usize>,
) -> (usize, usize, usize, usize, usize) {
    let s = 1usize << (k - 1);
    let v = geo.v1 + geo.v2;
    match method {
        Method::WholeStream => (1, n, s, 1, s * n),
        Method::TiledGlobal => {
            let frames = (n + geo.f - 1) / geo.f;
            // Table I row (b): O(2^{K−1} N (1 + 2L/D)); the paper's L is
            // the overlap length per side.
            let entries = s * n * (geo.f + 2 * v) / geo.f;
            (frames, geo.f + 2 * v, s, 1, entries)
        }
        Method::Unified => {
            let frames = (n + geo.f - 1) / geo.f;
            let tb_par = match f0 {
                Some(f0) => (geo.f + f0 - 1) / f0,
                None => 1,
            };
            (frames, geo.f + v, s, tb_par, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> SmemLayout {
        SmemLayout {
            k: 7,
            beta: 2,
            geo: FrameGeometry::new(256, 20, 45),
            f0: Some(32),
            fold_stages: None,
            reuse_arrays: false,
        }
    }

    #[test]
    fn optimized_is_much_smaller_than_naive() {
        let l = layout();
        let naive = l.naive().total();
        let opt = l.optimized().total();
        assert!(
            opt * 10 < naive,
            "optimized {opt} B should be ≥10× below naive {naive} B"
        );
    }

    #[test]
    fn branch_metric_halving() {
        // eq. (7) vs eq. (9): complement halving exactly halves the BM
        // array for β=2.
        let mut l = layout();
        l.fold_stages = None;
        let full_patterns = (1usize << l.beta) * l.span() * 4;
        assert_eq!(l.optimized().branch_metric_bytes * 2, full_patterns);
    }

    #[test]
    fn folding_shrinks_bm() {
        let mut l = layout();
        l.fold_stages = Some(32);
        let folded = l.optimized().branch_metric_bytes;
        l.fold_stages = None;
        let unfolded = l.optimized().branch_metric_bytes;
        assert_eq!(folded, 32 * 2 * 4);
        assert!(folded < unfolded);
    }

    #[test]
    fn survivors_bitpacked() {
        let l = layout();
        // 64 states → 8 B per stage.
        assert_eq!(l.optimized().survivor_bytes, 8 * l.span());
    }

    #[test]
    fn reuse_eliminates_llr_array() {
        let mut l = layout();
        l.reuse_arrays = true;
        let b = l.optimized();
        assert_eq!(b.llr_bytes, 0);
        assert_eq!(b.boundary_bytes, 0);
        // Shared array is the max of the two lifetimes.
        assert_eq!(b.survivor_bytes, (2 * l.span() * 4).max(8 * l.span()));
    }

    #[test]
    fn table1_proposed_uses_no_global_memory() {
        let geo = FrameGeometry::new(256, 20, 20);
        let (_, _, pm_par, tb_par, global) =
            global_memory_table(Method::Unified, 7, 1 << 20, geo, Some(32));
        assert_eq!(global, 0);
        assert_eq!(pm_par, 64);
        assert_eq!(tb_par, 8);
    }

    #[test]
    fn traceback_working_bytes_matches_layouts() {
        // K=7: 64 states → one u64 decision word per stage (8 B) plus
        // two 64-entry f32 PM rows (512 B).
        assert_eq!(traceback_working_bytes(64, 100), 8 * 100 + 512);
        // Sub-word state counts still pay one word per stage.
        assert_eq!(traceback_working_bytes(16, 10), 8 * 10 + 2 * 16 * 4);
    }

    #[test]
    fn sova_margins_cost_four_bytes_per_state_stage() {
        // K=7 (64 states), a 321-stage frame span: 4 B per (state,
        // stage) — one f32 margin each, no packing possible.
        assert_eq!(sova_margin_bytes(64, 321), 4 * 64 * 321);
        // The margins dwarf the 1-bit survivor storage by 32×: the
        // planner must see them or soft requests blow the budget.
        let surv_bits_bytes = 8 * 321; // one u64 word per stage at K=7
        assert_eq!(sova_margin_bytes(64, 321), 32 * surv_bits_bytes);
        assert_eq!(sova_margin_bytes(0, 100), 0);
        assert_eq!(sova_margin_bytes(16, 0), 0);
    }

    #[test]
    fn soft_working_set_exceeds_hard() {
        // A soft decode's resident set is the hard set plus margins —
        // strictly larger for any real geometry.
        let hard = traceback_working_bytes(64, 256);
        let soft = hard + sova_margin_bytes(64, 256);
        assert!(soft > hard);
        assert_eq!(soft - hard, 65536);
    }

    #[test]
    fn lane_survivors_are_one_bit_per_lane() {
        // A full 64-lane K=7 group: the survivor portion must account
        // exactly 1 bit per state per stage per lane.
        let states = 64;
        let stages = 321; // v1 + f + v2 at the paper's operating point
        let lanes = 64;
        let pm_bytes = 2 * states * lanes * 4;
        let survivor_bytes = lane_traceback_working_bytes(states, stages, lanes) - pm_bytes;
        assert_eq!(survivor_bytes, states * stages * lanes / 8);
        assert_eq!(survivor_bytes * 8, states * stages * lanes, "1 bit per decision");
    }

    #[test]
    fn lane_bytes_match_single_lane_baseline() {
        // A 1-lane group still pays a full u64 word per (stage, state)
        // (the packing unit), like the scalar layout pays a word per
        // stage for sub-word state counts.
        assert_eq!(lane_traceback_working_bytes(64, 100, 1), 64 * 100 * 8 + 2 * 64 * 4);
        // Widening lanes grows PM linearly but survivors not at all
        // until the 64-lane word is full.
        let narrow = lane_traceback_working_bytes(64, 100, 8);
        let wide = lane_traceback_working_bytes(64, 100, 64);
        assert_eq!(wide - narrow, 2 * 64 * (64 - 8) * 4);
    }

    #[test]
    fn tgemm_tiles_keep_small_codes_whole_and_split_large_ones() {
        // K ≤ 11 (half ≤ 512): one tile covers the whole butterfly.
        assert_eq!(tgemm_tile_states(64), 32); // K=7
        assert_eq!(tgemm_tile_states(256), 128); // K=9
        assert_eq!(tgemm_tile_states(1024), 512); // K=11
        // K=13 (half = 2048): the L1 budget forces a split.
        assert_eq!(tgemm_tile_states(4096), 512);
        assert!(tgemm_tile_states(4096) * TGEMM_TILE_BYTES_PER_INDEX <= TGEMM_L1_TILE_BUDGET);
        assert_eq!(tgemm_tile_states(1), 1);
    }

    #[test]
    fn tgemm_stage_batch_tracks_the_l2_budget() {
        // Small codes hit the 64-stage clamp; the slab still fits L2.
        assert_eq!(tgemm_stage_batch(64), 64); // K=7
        assert_eq!(tgemm_stage_batch(256), 64); // K=9
        // K=13: 4096 states × 4 B = 16 KiB/stage → 16 stages.
        assert_eq!(tgemm_stage_batch(4096), 16);
        for states in [64usize, 256, 1024, 4096, 32768] {
            let batch = tgemm_stage_batch(states);
            assert!((4..=64).contains(&batch), "{states} states: batch {batch}");
            assert!(
                batch == 4 || batch * states * 4 <= TGEMM_L2_SLAB_BUDGET,
                "{states} states: slab over budget"
            );
        }
        assert_eq!(tgemm_slab_bytes(256), 64 * 256 * 4);
    }

    #[test]
    fn table1_ordering() {
        let geo = FrameGeometry::new(256, 20, 20);
        let n = 1 << 20;
        let (_, _, _, _, ga) = global_memory_table(Method::WholeStream, 7, n, geo, None);
        let (_, _, _, _, gb) = global_memory_table(Method::TiledGlobal, 7, n, geo, None);
        let (_, _, _, _, gc) = global_memory_table(Method::Unified, 7, n, geo, Some(32));
        assert!(gb > ga, "tiled stores overlaps too");
        assert_eq!(gc, 0);
    }
}
