//! V100 occupancy + throughput model.
//!
//! The paper reports (via Visual Profiler) that *shared memory is the
//! bottleneck*: blocks/SM ≈ smem_per_sm / smem_per_block. Throughput
//! follows a two-term cost per frame,
//!
//! ```text
//! W  =  span · warps · c_fwd   +   tb_span · c_tb        [SM cycles]
//! Gb/s = sm_count · clock / W · f · min(1, blocks_per_sm / B_min) / 1e9
//! ```
//!
//! * the forward procedure is **issue-bound**: every stage all
//!   2^{k−1} states do an ACS butterfly, `warps = states/32` warps wide,
//!   `c_fwd` cycles of SM issue per warp per stage;
//! * the traceback is **latency-bound**: a dependent shared-memory
//!   pointer chase, `c_tb` cycles per step that cannot be hidden within
//!   the block — `f + v2` steps for the serial traceback (one walking
//!   thread, rest of the block idle) versus `f0 + v2` for the parallel
//!   traceback (all subframes walk concurrently in sibling lanes).
//!   This is the mechanism behind Table V's ≈2× gain over Table IV;
//! * `B_min` resident blocks are needed to hide memory latency; the
//!   survivor matrix (1 B per state per stage in the paper's layout)
//!   is what pushes big-f blocks below that — producing Table IV's
//!   rise-then-fall in f.
//!
//! `c_fwd`/`c_tb` are calibrated once against two anchor cells of
//! Table IV/V (f=128/v2=10 and f0=24/v2=25); every other cell is a
//! model output. Our Pallas kernel bit-packs survivors (8× smaller);
//! the `paper_layout` flag selects which layout the model assumes.

use crate::frames::plan::FrameGeometry;
use super::smem::SmemLayout;

/// GPU hardware parameters (defaults = Tesla V100 SXM2).
#[derive(Debug, Clone, Copy)]
pub struct GpuParams {
    pub name: &'static str,
    pub sm_count: usize,
    /// Shared memory per SM in bytes (V100: up to 96 KiB usable).
    pub smem_per_sm: usize,
    /// Max resident blocks per SM.
    pub max_blocks_per_sm: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// SM clock in Hz.
    pub clock_hz: f64,
    /// SM issue cycles per warp per forward stage (calibrated).
    pub cycles_fwd_per_warp_stage: f64,
    /// Unhideable cycles per traceback step (calibrated).
    pub cycles_tb_per_step: f64,
    /// Resident blocks per SM needed to hide memory latency.
    pub min_blocks_full_rate: usize,
}

impl GpuParams {
    pub fn v100() -> Self {
        GpuParams {
            name: "Tesla V100",
            sm_count: 80,
            smem_per_sm: 96 * 1024,
            max_blocks_per_sm: 32,
            max_threads_per_sm: 2048,
            clock_hz: 1.38e9,
            cycles_fwd_per_warp_stage: 2.3,
            cycles_tb_per_step: 11.6,
            min_blocks_full_rate: 4,
        }
    }
}

/// Model output for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct ThroughputEstimate {
    pub blocks_per_sm: usize,
    pub resident_blocks: usize,
    pub smem_per_block: usize,
    /// SM cycles charged per frame.
    pub cycles_per_frame: f64,
    /// Latency-hiding utilization factor ∈ (0, 1].
    pub utilization: f64,
    /// Decoded information bits per second, whole GPU.
    pub gbps: f64,
}

/// The occupancy model.
#[derive(Debug, Clone, Copy)]
pub struct OccupancyModel {
    pub gpu: GpuParams,
    pub k: u32,
    pub beta: u32,
    /// Assume the paper's survivor layout (1 B per state per stage)
    /// instead of our bit-packed layout, for apples-to-apples
    /// reproduction of Tables IV/V.
    pub paper_layout: bool,
}

impl OccupancyModel {
    pub fn new(gpu: GpuParams, k: u32, beta: u32) -> Self {
        OccupancyModel { gpu, k, beta, paper_layout: true }
    }

    fn states(&self) -> usize {
        1usize << (self.k - 1)
    }

    /// Shared-memory bytes per block for a frame geometry.
    pub fn smem_per_block(&self, geo: FrameGeometry, f0: Option<usize>) -> usize {
        let layout = SmemLayout {
            k: self.k,
            beta: self.beta,
            geo,
            f0,
            fold_stages: Some(32),
            reuse_arrays: true,
        };
        if self.paper_layout {
            // Survivors as 1 byte per state per stage (not bit-packed),
            // LLR array reused, folded branch metrics, ping-pong PM.
            let span = geo.span();
            let sp = self.states() * span;
            let pm = 2 * self.states() * 4;
            let bm = (1usize << (self.beta - 1)) * 32 * 4;
            let boundary = match f0 {
                Some(f0) => (geo.f + f0 - 1) / f0 * 4,
                None => 0,
            };
            sp + pm + bm + boundary
        } else {
            layout.optimized().total()
        }
    }

    /// Estimate throughput for the serial-traceback tiled kernel
    /// (Table IV rows).
    pub fn serial_traceback(&self, geo: FrameGeometry) -> ThroughputEstimate {
        let tb_steps = (geo.f + geo.v2) as f64;
        self.finish(geo, self.smem_per_block(geo, None), tb_steps)
    }

    /// Estimate throughput for the unified parallel-traceback kernel
    /// (Table V rows).
    pub fn parallel_traceback(&self, geo: FrameGeometry, f0: usize) -> ThroughputEstimate {
        let n_sub = (geo.f + f0 - 1) / f0;
        // All subframes walk concurrently; if there are more subframes
        // than threads they serialize in waves (never happens for the
        // paper's parameter ranges).
        let waves = ((n_sub + self.states() - 1) / self.states()).max(1) as f64;
        let tb_steps = (f0 + geo.v2) as f64 * waves;
        self.finish(geo, self.smem_per_block(geo, Some(f0)), tb_steps)
    }

    fn finish(&self, geo: FrameGeometry, smem: usize, tb_steps: f64) -> ThroughputEstimate {
        let g = &self.gpu;
        let by_smem = if smem == 0 { usize::MAX } else { g.smem_per_sm / smem };
        let threads_per_block = self.states().max(32);
        let by_threads = g.max_threads_per_sm / threads_per_block;
        let blocks_per_sm = by_smem.min(by_threads).min(g.max_blocks_per_sm);
        let warps = (self.states() as f64 / 32.0).max(1.0);
        let cycles = geo.span() as f64 * warps * g.cycles_fwd_per_warp_stage
            + tb_steps * g.cycles_tb_per_step;
        let utilization = if blocks_per_sm == 0 {
            0.0
        } else {
            (blocks_per_sm as f64 / g.min_blocks_full_rate as f64).min(1.0)
        };
        let frames_per_s_per_sm = g.clock_hz / cycles * utilization;
        let gbps = frames_per_s_per_sm * g.sm_count as f64 * geo.f as f64 / 1e9;
        ThroughputEstimate {
            blocks_per_sm,
            resident_blocks: blocks_per_sm * g.sm_count,
            smem_per_block: smem,
            cycles_per_frame: cycles,
            utilization,
            gbps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> OccupancyModel {
        OccupancyModel::new(GpuParams::v100(), 7, 2)
    }

    #[test]
    fn parallel_tb_beats_serial_tb() {
        // Table V vs Table IV at BER-comparable cells (paper §V-C):
        // serial f=256/v2=20 (6.05 Gb/s) vs parallel f0=32/v2=45
        // (5.84)… and serial f=256/v2=20 vs parallel f0=24/v2=25 when
        // comparing at matched *throughput-optimal* settings gives ≈2×.
        let m = model();
        let serial = m.serial_traceback(FrameGeometry::new(256, 20, 20));
        let parallel = m.parallel_traceback(FrameGeometry::new(256, 20, 25), 24);
        let gain = parallel.gbps / serial.gbps;
        assert!(
            gain > 1.5 && gain < 4.0,
            "parallel/serial gain {gain:.2} (serial {:.2}, parallel {:.2} Gb/s)",
            serial.gbps,
            parallel.gbps
        );
    }

    #[test]
    fn anchors_within_2x_of_paper() {
        // Table IV f=128, v2=10 → 6.64 Gb/s; Table V f0=24, v2=25 → 13.7.
        let m = model();
        let a = m.serial_traceback(FrameGeometry::new(128, 20, 10)).gbps;
        assert!(a > 3.3 && a < 13.3, "serial anchor {a:.2} Gb/s vs paper 6.64");
        let b = m.parallel_traceback(FrameGeometry::new(256, 20, 25), 24).gbps;
        assert!(b > 6.8 && b < 27.4, "parallel anchor {b:.2} Gb/s vs paper 13.7");
    }

    #[test]
    fn throughput_decreases_with_v2() {
        let m = model();
        let mut prev = f64::INFINITY;
        for v2 in [10, 20, 30, 40] {
            let t = m.serial_traceback(FrameGeometry::new(128, 20, v2)).gbps;
            assert!(t < prev, "v2={v2}: {t} !< {prev}");
            prev = t;
        }
        prev = f64::INFINITY;
        for v2 in [25, 30, 35, 40, 45] {
            let t = m.parallel_traceback(FrameGeometry::new(256, 20, v2), 32).gbps;
            assert!(t < prev, "ptb v2={v2}: {t} !< {prev}");
            prev = t;
        }
    }

    #[test]
    fn throughput_peaks_in_f() {
        // Table IV shape: rising from f=32, peaking mid-range (128/256),
        // falling by f=512 (occupancy loss from the survivor matrix).
        let m = model();
        let g: Vec<f64> = [32usize, 64, 128, 256, 512]
            .iter()
            .map(|&f| m.serial_traceback(FrameGeometry::new(f, 20, 20)).gbps)
            .collect();
        assert!(g[1] > g[0], "f=64 > f=32: {g:?}");
        assert!(g[2] > g[1], "f=128 > f=64: {g:?}");
        let peak = g.iter().cloned().fold(f64::MIN, f64::max);
        assert!(peak > g[4], "peak above f=512: {g:?}");
    }

    #[test]
    fn occupancy_respects_limits() {
        let m = model();
        let e = m.serial_traceback(FrameGeometry::new(32, 20, 10));
        assert!(e.blocks_per_sm <= m.gpu.max_blocks_per_sm);
        assert!(e.blocks_per_sm >= 1);
        assert!(e.blocks_per_sm <= 32); // thread limit: 2048/64
        assert!(e.utilization > 0.0 && e.utilization <= 1.0);
    }

    #[test]
    fn bitpacked_layout_fits_more_blocks() {
        // Our kernel's bit-packed survivors admit more resident blocks
        // than the paper's byte-per-state layout — the §Perf ablation.
        let mut m = model();
        let geo = FrameGeometry::new(512, 20, 20);
        let paper = m.serial_traceback(geo);
        m.paper_layout = false;
        let packed = m.serial_traceback(geo);
        assert!(
            packed.blocks_per_sm > paper.blocks_per_sm,
            "bitpacked {} vs paper {}",
            packed.blocks_per_sm,
            paper.blocks_per_sm
        );
        assert!(packed.gbps >= paper.gbps);
    }

    #[test]
    fn smaller_frames_need_less_smem() {
        let m = model();
        let small = m.serial_traceback(FrameGeometry::new(32, 20, 10)).smem_per_block;
        let big = m.serial_traceback(FrameGeometry::new(512, 20, 10)).smem_per_block;
        assert!(small < big);
    }
}
