//! Tuner invariants: (1) the planner always returns a registered
//! engine and respects its memory budget for arbitrary geometries,
//! including K/frame shapes far outside any calibrated grid; (2) the
//! `auto` engine is bit-exact with `unified` across K=5/7/9 for both
//! terminated and truncated streams — adaptive dispatch is an
//! execution-placement decision only, never an output change.

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::tuner::{
    CalibrationProfile, CalibrationRecord, JobShape, Planner, PlannerConfig,
    BLOCKS_STREAM_MIN, DISPATCH_CANDIDATES, TGEMM_K_MIN,
};
use viterbi::util::check;
use viterbi::viterbi::{registry, BuildParams, DecodeRequest, Engine as _, StreamEnd};

fn gen_shape(rng: &mut Rng64) -> (JobShape, Option<usize>, usize) {
    let shape = JobShape {
        k: rng.gen_range_usize(3, 17) as u32,
        frame_len: rng.gen_range_usize(1, 2048),
        v1: rng.gen_range_usize(0, 48),
        v2: rng.gen_range_usize(0, 64),
        batch_frames: rng.gen_range_usize(1, 512),
        uniform: rng.next_u64() & 1 == 0,
        soft: rng.next_u64() & 3 == 0,
        tail_biting: rng.next_u64() & 3 == 0,
        // A quarter of the shapes are one contiguous stream, with
        // lengths landing on both sides of the block-stream threshold.
        stream_stages: if rng.next_u64() & 3 == 0 {
            rng.gen_range_usize(1, 1 << 17)
        } else {
            0
        },
    };
    let budget = if rng.next_u64() & 1 == 0 {
        Some(rng.gen_range_usize(1, 1 << 26))
    } else {
        None
    };
    let threads = rng.gen_range_usize(1, 9);
    (shape, budget, threads)
}

fn assert_plan_invariants(planner: &Planner, shape: &JobShape, budget: Option<usize>) {
    let choice = planner.plan(shape);
    // (a) Always a registered engine. Tail-biting shapes go to the
    // only circular-capable candidate; soft shapes only to
    // SOVA-capable engines; everything else stays within the
    // bit-exact dispatch family.
    let entry = registry::find(choice.engine)
        .unwrap_or_else(|| panic!("planner returned unregistered engine {:?}", choice.engine));
    if shape.tail_biting {
        assert_eq!(choice.engine, "wava", "tail-biting shape {shape:?}");
        assert!(entry.tail_biting);
    } else if shape.soft {
        assert!(
            entry.soft_output,
            "soft shape {shape:?} routed to non-soft {}",
            choice.engine
        );
    } else if shape.stream_stages >= BLOCKS_STREAM_MIN {
        // One contiguous long hard linear stream: the whole-stream
        // routes (block-parallel, and the tropical-matrix sweep for
        // large K) are eligible and win whenever the budget allows.
        assert!(
            choice.engine == "blocks"
                || choice.engine == "tgemm"
                || DISPATCH_CANDIDATES.contains(&choice.engine),
            "stream shape {shape:?} routed to non-candidate {:?}",
            choice.engine
        );
        if budget.is_none() {
            let expected = if shape.k >= TGEMM_K_MIN { "tgemm" } else { "blocks" };
            assert_eq!(choice.engine, expected, "unbudgeted stream shape {shape:?}");
        }
    } else {
        assert!(
            DISPATCH_CANDIDATES.contains(&choice.engine),
            "planner returned non-candidate {:?}",
            choice.engine
        );
    }
    // (b) Ragged shapes never get a lane engine.
    if !shape.uniform {
        assert!(
            !choice.engine.starts_with("lanes"),
            "ragged shape {shape:?} routed to {}",
            choice.engine
        );
    }
    // (c) The budget holds whenever it is satisfiable; otherwise the
    // planner degrades to the smallest-footprint candidate.
    if let Some(b) = budget {
        let ranked = planner.rank(shape);
        assert!(!ranked.is_empty());
        if ranked.iter().any(|c| c.working_set_bytes <= b) {
            assert!(
                choice.working_set_bytes <= b,
                "shape {shape:?}: picked {} at {} B over budget {b} B",
                choice.engine,
                choice.working_set_bytes
            );
        } else {
            let min = ranked.iter().map(|c| c.working_set_bytes).min().unwrap();
            assert_eq!(
                choice.working_set_bytes, min,
                "infeasible budget must degrade to the smallest candidate"
            );
        }
    }
}

#[test]
fn planner_returns_registered_engine_within_budget_for_arbitrary_shapes() {
    check::forall(
        "planner registry + budget invariants (heuristic)",
        250,
        0x7A9E_0001,
        gen_shape,
        |&(shape, budget, threads)| {
            let cfg = PlannerConfig { threads, lanes: 64, f0: 32, budget_bytes: budget };
            assert_plan_invariants(&Planner::heuristic(cfg), &shape, budget);
        },
    );
}

#[test]
fn planner_invariants_hold_with_a_profile_loaded() {
    // A small synthetic profile (deliberately not covering most query
    // shapes — K up to 16, frames up to 2048 — so nearest-cell
    // interpolation is exercised off-grid).
    let rec = |engine: &str, k: u32, f: usize, b: usize, mbps: f64| CalibrationRecord {
        engine: engine.into(),
        k,
        frame_len: f,
        batch_frames: b,
        lanes: if engine.starts_with("lanes") { b.min(64) } else { 1 },
        threads: 4,
        median_mbps: mbps,
        working_set_bytes: 4096,
        samples: 3,
        seed: 7,
    };
    let profile = CalibrationProfile::new(vec![
        rec("unified", 7, 256, 1, 30.0),
        rec("parallel", 7, 256, 64, 90.0),
        rec("lanes", 7, 256, 64, 150.0),
        rec("lanes-mt", 7, 256, 64, 260.0),
        rec("unified", 5, 64, 1, 95.0),
        rec("lanes", 5, 64, 64, 500.0),
    ]);
    check::forall(
        "planner registry + budget invariants (profile)",
        250,
        0x7A9E_0002,
        gen_shape,
        |&(shape, budget, threads)| {
            let cfg = PlannerConfig { threads, lanes: 64, f0: 32, budget_bytes: budget };
            let planner = Planner::with_profile(cfg, profile.clone());
            assert_plan_invariants(&planner, &shape, budget);
        },
    );
}

fn noisy_workload(
    spec: &CodeSpec,
    n: usize,
    ebn0: f64,
    seed: u64,
    term: Termination,
) -> (Vec<f32>, usize) {
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(spec, &bits, term);
    let stages = match term {
        Termination::Terminated => n + (spec.k as usize - 1),
        _ => n,
    };
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    (llr::llrs_from_samples(&rx, ch.sigma()), stages)
}

#[test]
fn auto_is_bit_exact_with_unified_across_k_and_termination() {
    // The parity grid of the acceptance criteria: K=5/7/9 ×
    // terminated/truncated, noisy channel, several frame batches per
    // stream (so the dispatcher actually exercises batched routes).
    for (spec, seed) in [
        (CodeSpec::standard_k5(), 0x5A_u64),
        (CodeSpec::standard_k7(), 0x7A_u64),
        (CodeSpec::standard_k9(), 0x9A_u64),
    ] {
        for (term, end) in [
            (Termination::Terminated, StreamEnd::Terminated),
            (Termination::Truncated, StreamEnd::Truncated),
        ] {
            let (llrs, stages) = noisy_workload(&spec, 64 * 21 - 9, 3.0, seed, term);
            let params = BuildParams {
                spec: spec.clone(),
                geo: FrameGeometry::new(64, 12, 20),
                f0: 16,
                threads: 4,
                delay: 96,
                lanes: 8,
                stream_stages: stages,
            };
            let auto = (registry::find("auto").unwrap().build)(&params);
            let unified = (registry::find("unified").unwrap().build)(&params);
            let a = auto
                .decode(&DecodeRequest::hard(&llrs, stages, end))
                .expect("auto decode")
                .bits;
            let u = unified
                .decode(&DecodeRequest::hard(&llrs, stages, end))
                .expect("unified decode")
                .bits;
            assert_eq!(
                a,
                u,
                "auto ({}) diverged from unified at K={} {:?}",
                auto.name(),
                spec.k,
                term
            );
        }
    }
}

#[test]
fn auto_single_frame_stream_matches_unified_too() {
    // The unified-route end of the dispatch spectrum.
    let spec = CodeSpec::standard_k7();
    let (llrs, stages) = noisy_workload(&spec, 50, 4.0, 0x51, Termination::Truncated);
    let params = BuildParams {
        spec: spec.clone(),
        geo: FrameGeometry::new(64, 12, 20),
        f0: 16,
        threads: 4,
        delay: 96,
        lanes: 8,
        stream_stages: stages,
    };
    let auto = (registry::find("auto").unwrap().build)(&params);
    let unified = (registry::find("unified").unwrap().build)(&params);
    let req = DecodeRequest::hard(&llrs, stages, StreamEnd::Truncated);
    assert_eq!(auto.decode(&req).unwrap().bits, unified.decode(&req).unwrap().bits);
}
