//! Engine API contract tests, registry-wide: typed errors instead of
//! panics on malformed input, soft-output capability matching each
//! entry's `soft_output` flag, and the SOVA acceptance criterion
//! (high-confidence bits have a strictly lower BER than low-confidence
//! bits at Eb/N0 = 3 dB).

use viterbi::ber::{measure_soft_split, BerConfig};
use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::viterbi::{registry, BuildParams, DecodeError, DecodeRequest, Engine as _, StreamEnd};

fn params() -> BuildParams {
    BuildParams {
        spec: CodeSpec::standard_k7(),
        geo: FrameGeometry::new(64, 12, 20),
        f0: 16,
        threads: 2,
        delay: 96,
        lanes: 8,
        stream_stages: 1024,
    }
}

fn noisy_workload(n: usize, ebn0: f64, seed: u64) -> (Vec<u8>, Vec<f32>, usize) {
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Terminated);
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    (bits, llr::llrs_from_samples(&rx, ch.sigma()), n + 6)
}

#[test]
fn every_engine_returns_typed_error_on_wrong_llr_length() {
    // The seed-era API asserted; the redesigned API must answer with
    // DecodeError::LlrLengthMismatch — for every registry engine.
    let p = params();
    let stages = 512usize;
    let llrs = vec![0.5f32; stages * 2 - 3];
    for entry in registry() {
        let engine = (entry.build)(&p);
        let err = engine
            .decode(&DecodeRequest::hard(&llrs, stages, StreamEnd::Truncated))
            .err()
            .unwrap_or_else(|| panic!("{} accepted malformed LLRs", entry.name));
        assert_eq!(
            err,
            DecodeError::LlrLengthMismatch { expected: 1024, got: 1021 },
            "{}",
            entry.name
        );
        // Soft requests validate the length too (before negotiating
        // the output mode, so the more actionable error wins).
        let err = engine
            .decode(&DecodeRequest::soft(&llrs, stages, StreamEnd::Truncated))
            .err()
            .unwrap_or_else(|| panic!("{} accepted malformed soft request", entry.name));
        assert!(
            matches!(err, DecodeError::LlrLengthMismatch { .. }),
            "{}: {err}",
            entry.name
        );
    }
}

#[test]
fn soft_capability_matches_registry_flag() {
    let p = params();
    let (bits, llrs, stages) = noisy_workload(1000, 4.0, 0xA921);
    for entry in registry() {
        let engine = (entry.build)(&p);
        let result = engine.decode(&DecodeRequest::soft(&llrs, stages, StreamEnd::Terminated));
        if entry.soft_output {
            let out = result.unwrap_or_else(|e| panic!("{}: {e}", entry.name));
            let soft = out.soft.expect("soft requested");
            assert_eq!(soft.len(), stages, "{}", entry.name);
            for (t, (&b, &s)) in out.bits.iter().zip(&soft).enumerate() {
                assert_eq!(
                    b == 1,
                    s.is_sign_negative(),
                    "{}: soft sign disagrees with bit at {t}",
                    entry.name
                );
            }
            // At 4 dB the decode itself is still essentially clean.
            let errs = viterbi::util::bits::count_bit_errors(&out.bits[..bits.len()], &bits);
            assert!(errs < 5, "{}: {errs} errors at 4 dB", entry.name);
        } else {
            let err = result.err().unwrap_or_else(|| {
                panic!("{} has soft_output=false but accepted a soft request", entry.name)
            });
            assert!(
                matches!(err, DecodeError::UnsupportedOutput { .. }),
                "{}: wrong error {err}",
                entry.name
            );
        }
    }
}

#[test]
fn hard_requests_never_return_soft_values() {
    let p = params();
    let (_bits, llrs, stages) = noisy_workload(500, 5.0, 0x5EED);
    for entry in registry() {
        let engine = (entry.build)(&p);
        let out = engine
            .decode(&DecodeRequest::hard(&llrs, stages, StreamEnd::Terminated))
            .unwrap_or_else(|e| panic!("{}: {e}", entry.name));
        assert!(out.soft.is_none(), "{}", entry.name);
        assert_eq!(out.bits.len(), stages, "{}", entry.name);
        assert!(out.stats.frames >= 1, "{}", entry.name);
    }
}

#[test]
fn request_api_replaces_the_deprecated_stream_shim() {
    // Migrated from the decode_stream shim's test: the request API
    // decodes the same bits and answers the shim's old panic
    // conditions with typed errors.
    let p = params();
    let (bits, llrs, stages) = noisy_workload(800, 6.0, 0x0DD);
    let engine = (registry()[0].build)(&p);
    let out = engine
        .decode(&DecodeRequest::hard(&llrs, stages, StreamEnd::Terminated))
        .expect("well-formed request decodes");
    assert_eq!(&out.bits[..bits.len()], &bits[..]);
    // Former panic path: malformed length is a typed value now.
    let err = engine
        .decode(&DecodeRequest::hard(&llrs[2..], stages, StreamEnd::Terminated))
        .unwrap_err();
    assert!(matches!(err, DecodeError::LlrLengthMismatch { .. }), "{err}");
}

#[test]
fn tail_biting_capability_matches_registry_flag() {
    // Every engine either decodes a tail-biting stream (the wava
    // engine and the auto dispatcher that routes to it) or answers the
    // typed DecodeError::UnsupportedStreamEnd — never a panic, never a
    // silent linear decode.
    let p = params();
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(0x7B17);
    let mut bits = vec![0u8; 160];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::TailBiting);
    let llrs: Vec<f32> = enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
    for entry in registry() {
        let engine = (entry.build)(&p);
        let result =
            engine.decode(&DecodeRequest::hard(&llrs, bits.len(), StreamEnd::TailBiting));
        if entry.tail_biting {
            let out = result.unwrap_or_else(|e| panic!("{}: {e}", entry.name));
            assert_eq!(out.bits, bits, "{}: noiseless tail-biting decode", entry.name);
        } else {
            let err = result.err().unwrap_or_else(|| {
                panic!("{} has tail_biting=false but accepted the request", entry.name)
            });
            assert!(
                matches!(err, DecodeError::UnsupportedStreamEnd { .. }),
                "{}: wrong error {err}",
                entry.name
            );
            assert!(err.to_string().contains("tail-biting"), "{}: {err}", entry.name);
        }
    }
}

#[test]
fn tail_biting_soft_requests_refused_until_sova_is_ported() {
    // TailBiting + Soft on the capable engines answers
    // UnsupportedOutput (circular SOVA is not implemented), and
    // length validation still wins over both negotiations.
    let p = params();
    let llrs = vec![0.5f32; 320];
    for name in ["wava", "auto"] {
        let engine = (viterbi::viterbi::registry::find(name).unwrap().build)(&p);
        let err = engine
            .decode(&DecodeRequest::soft(&llrs, 160, StreamEnd::TailBiting))
            .unwrap_err();
        assert!(
            matches!(err, DecodeError::UnsupportedOutput { .. }),
            "{name}: wrong error {err}"
        );
        let err = engine
            .decode(&DecodeRequest::hard(&llrs[..319], 160, StreamEnd::TailBiting))
            .unwrap_err();
        assert!(
            matches!(err, DecodeError::LlrLengthMismatch { .. }),
            "{name}: wrong error {err}"
        );
    }
}

#[test]
fn tgemm_refusals_are_typed_and_name_the_engine() {
    // The tropical-matrix engine is hard-output / linear-stream only;
    // both refusals must be the typed variants carrying the engine's
    // own name, so callers can tell which route in a dispatch chain
    // declined the request.
    use viterbi::viterbi::OutputMode;
    let p = params();
    let engine = (viterbi::viterbi::registry::find("tgemm").unwrap().build)(&p);
    let llrs = vec![0.5f32; 320];
    match engine.decode(&DecodeRequest::soft(&llrs, 160, StreamEnd::Truncated)) {
        Err(DecodeError::UnsupportedOutput { engine: name, mode }) => {
            assert!(name.starts_with("tgemm"), "{name}");
            assert_eq!(mode, OutputMode::Soft);
        }
        other => panic!("soft request must be a typed refusal, got {other:?}"),
    }
    match engine.decode(&DecodeRequest::hard(&llrs, 160, StreamEnd::TailBiting)) {
        Err(DecodeError::UnsupportedStreamEnd { engine: name, end }) => {
            assert!(name.starts_with("tgemm"), "{name}");
            assert_eq!(end, StreamEnd::TailBiting);
        }
        other => panic!("tail-biting request must be a typed refusal, got {other:?}"),
    }
    // Length validation still wins over capability negotiation.
    let err = engine
        .decode(&DecodeRequest::soft(&llrs[..319], 160, StreamEnd::Truncated))
        .unwrap_err();
    assert!(matches!(err, DecodeError::LlrLengthMismatch { .. }), "{err}");
}

#[test]
fn sova_reliabilities_separate_errors_for_scalar_and_unified() {
    // The headline acceptance criterion: at Eb/N0 = 3 dB, bits the
    // decoder marks confident (|soft| above the median) must show a
    // strictly lower BER than bits it marks doubtful.
    let spec = CodeSpec::standard_k7();
    let cfg = BerConfig {
        block_bits: 8192,
        target_errors: 80,
        max_bits: 800_000,
        seed: 0x50FA_CE,
        puncture: None,
    };
    for name in ["scalar", "unified"] {
        let entry = viterbi::viterbi::registry::find(name).unwrap();
        assert!(entry.soft_output, "{name} must advertise soft output");
        let mut p = params();
        p.geo = FrameGeometry::new(256, 20, 45);
        p.f0 = 32;
        let engine = (entry.build)(&p);
        let split = measure_soft_split(&spec, engine.as_ref(), &cfg, 3.0)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(split.reliable, "{name}: not enough errors observed {split:?}");
        assert!(
            split.separates(),
            "{name}: high-conf BER {:.3e} not below low-conf BER {:.3e}",
            split.high_conf_ber,
            split.low_conf_ber
        );
        assert!(
            split.high_conf_ber * 2.0 < split.low_conf_ber,
            "{name}: confidence split too weak {split:?}"
        );
    }
}
