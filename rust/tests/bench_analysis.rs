//! Golden tests for the perf-trajectory analysis commands over
//! committed fixture record sets: `bench diff` alignment and
//! classification, `bench rank` standings, `bench cmp` side-by-side,
//! and the lenient reader's superseded-schema skipping. The fixtures
//! (`tests/fixtures/BENCH_old.jsonl` / `BENCH_new.jsonl`) encode one
//! of each outcome — an unchanged cell, a regression, an improvement,
//! an added engine, a removed engine, and a skipped v2 line — so every
//! classification path is pinned against real files, not in-memory
//! records.

use std::path::PathBuf;

use viterbi::bench::{cmp, diff, rank, read_jsonl_lenient, DeltaClass, DiffOptions};

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

#[test]
fn lenient_reader_skips_the_v2_line_in_the_old_fixture() {
    let old = read_jsonl_lenient(&fixture("BENCH_old.jsonl")).unwrap();
    assert_eq!(old.skipped_old, 1, "exactly the one v2 line is skipped");
    assert_eq!(old.records.len(), 4);
    let new = read_jsonl_lenient(&fixture("BENCH_new.jsonl")).unwrap();
    assert_eq!(new.skipped_old, 0);
    assert_eq!(new.records.len(), 4);
}

#[test]
fn golden_diff_between_the_committed_fixtures() {
    let old = read_jsonl_lenient(&fixture("BENCH_old.jsonl")).unwrap().records;
    let new = read_jsonl_lenient(&fixture("BENCH_new.jsonl")).unwrap().records;
    let report = diff(&old, &new, &DiffOptions::default()).unwrap();

    // Matched cells keep the old set's order: scalar, unified, lanes.
    let classes: Vec<(&str, DeltaClass)> = report
        .entries
        .iter()
        .map(|e| (e.key.engine.as_str(), e.class))
        .collect();
    assert_eq!(
        classes,
        vec![
            ("scalar", DeltaClass::Unchanged),
            ("unified", DeltaClass::Regression),
            ("lanes", DeltaClass::Improvement),
        ]
    );
    assert!((report.entries[0].delta_pct - 0.857).abs() < 0.01, "{}", report.entries[0].delta_pct);
    assert!((report.entries[1].delta_pct + 15.0).abs() < 1e-9, "{}", report.entries[1].delta_pct);
    assert!((report.entries[2].delta_pct - 20.0).abs() < 1e-9, "{}", report.entries[2].delta_pct);

    // streaming appears only in the new set, blocks only in the old.
    assert_eq!(report.added.len(), 1);
    assert_eq!(report.added[0].engine, "streaming");
    assert_eq!(report.removed.len(), 1);
    assert_eq!(report.removed[0].engine, "blocks");
    assert!(report.has_regressions(), "the unified -15% cell gates");

    let table = report.render();
    assert!(table.contains("REGRESSION"), "{table}");
    assert!(table.contains("improved"), "{table}");
    assert!(table.contains("(only in new set)"), "{table}");
    assert!(table.contains("(only in old set)"), "{table}");
    assert!(
        table.contains("summary: 3 matched, 1 regression(s), 1 improvement(s), 1 added, 1 removed"),
        "{table}"
    );
}

#[test]
fn widening_the_noise_threshold_clears_the_regression() {
    let old = read_jsonl_lenient(&fixture("BENCH_old.jsonl")).unwrap().records;
    let new = read_jsonl_lenient(&fixture("BENCH_new.jsonl")).unwrap().records;
    let opts = DiffOptions { threshold_pct: 16.0, normalize: None };
    let report = diff(&old, &new, &opts).unwrap();
    assert!(!report.has_regressions(), "-15% is inside ±16%");
    assert_eq!(report.improvements().len(), 1, "+20% still clears ±16%");
    assert_eq!(report.improvements()[0].key.engine, "lanes");
}

#[test]
fn rank_orders_the_new_fixture_by_throughput() {
    let new = read_jsonl_lenient(&fixture("BENCH_new.jsonl")).unwrap().records;
    let report = rank(&new).unwrap();
    assert_eq!(report.scenarios.len(), 1, "one K=7/f=256/b=64 scenario");
    let rows = &report.scenarios[0].rows;
    let order: Vec<&str> = rows.iter().map(|r| r.key.engine.as_str()).collect();
    assert_eq!(order, vec!["lanes", "unified", "scalar", "streaming"]);
    assert!((rows[0].ratio - 1.0).abs() < 1e-12, "the winner's ratio is 1");
    assert!(rows[3].ratio > 15.0, "streaming trails lanes 15x: {}", rows[3].ratio);
    // Engine standings: best geomean first; one scenario, so the
    // geomean is just each engine's ratio.
    assert_eq!(report.engines[0].engine, "lanes");
    assert_eq!(report.engines[0].wins, 1);
    let rendered = report.render();
    assert!(rendered.contains("lanes"), "{rendered}");
}

#[test]
fn cmp_lays_the_fixture_sets_side_by_side() {
    let old = read_jsonl_lenient(&fixture("BENCH_old.jsonl")).unwrap().records;
    let new = read_jsonl_lenient(&fixture("BENCH_new.jsonl")).unwrap().records;
    let report = cmp(&[("old".to_string(), old), ("new".to_string(), new)]).unwrap();
    // Union of cells in first-seen order: the old set's four engines,
    // then the engine only the new set has.
    let engines: Vec<&str> = report.rows.iter().map(|r| r.key.engine.as_str()).collect();
    assert_eq!(engines, vec!["scalar", "unified", "lanes", "blocks", "streaming"]);
    let blocks = &report.rows[3];
    assert!(blocks.cells[0].is_some() && blocks.cells[1].is_none(), "blocks only in old");
    let streaming = &report.rows[4];
    assert!(streaming.cells[0].is_none() && streaming.cells[1].is_some());
    let rendered = report.render();
    assert!(rendered.contains("(absent)"), "{rendered}");
    assert!(rendered.contains("Mb/s"), "{rendered}");
    assert!(rendered.contains("acs-µs"), "{rendered}");
}
