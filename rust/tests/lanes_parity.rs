//! Bit-exactness parity: the lane-batched engines (`lanes`,
//! `lanes-mt`) must produce **identical** output to the `unified`
//! engine — not merely equal BER — on every code, SNR and stream
//! shape, including ragged lane-group tails. Lane batching is a pure
//! execution-layout change; any output difference is a defect.

use std::sync::Arc;

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::lanes::{LanesEngine, LanesMtEngine};
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelTraceback, StartPolicy, StreamEnd, TiledEngine,
    TracebackMode,
};

fn run(e: &dyn Engine, llrs: &[f32], stages: usize, end: StreamEnd) -> Vec<u8> {
    e.decode(&DecodeRequest::hard(llrs, stages, end)).expect("decode").bits
}

/// Noisy terminated workload for `spec` at `ebn0` dB.
fn workload(spec: &CodeSpec, n: usize, ebn0: f64, seed: u64) -> (Vec<f32>, usize) {
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(spec, &bits, Termination::Terminated);
    let stages = n + (spec.k as usize - 1);
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    (llr::llrs_from_samples(&rx, ch.sigma()), stages)
}

#[test]
fn lanes_and_lanes_mt_match_unified_bit_for_bit() {
    let pool = Arc::new(ThreadPool::new(4));
    let codes: [(CodeSpec, FrameGeometry, usize); 3] = [
        (CodeSpec::standard_k5(), FrameGeometry::new(64, 8, 16), 8),
        (CodeSpec::standard_k7(), FrameGeometry::new(128, 20, 45), 16),
        (CodeSpec::standard_k9(), FrameGeometry::new(128, 24, 60), 16),
    ];
    for (ci, (spec, geo, f0)) in codes.iter().enumerate() {
        for (si, &snr) in [0.0f64, 3.0, 6.0].iter().enumerate() {
            for rep in 0..2u64 {
                let seed =
                    0x51D_u64 ^ ((ci as u64) << 8) ^ ((si as u64) << 16) ^ (rep << 24);
                // A non-multiple of any lane width, so the last lane
                // group is ragged.
                let n = geo.f * 11 - 37 + (rep as usize) * 13;
                let (llrs, stages) = workload(spec, n, snr, seed);
                let ptb = ParallelTraceback::new(*f0, geo.v2, StartPolicy::StoredArgmax);
                let unified =
                    TiledEngine::new(spec.clone(), *geo, TracebackMode::Parallel(ptb));
                let reference = run(&unified, &llrs, stages, StreamEnd::Terminated);

                for lanes in [4usize, 64] {
                    let e = LanesEngine::new(spec.clone(), *geo, ptb, lanes);
                    let out = run(&e, &llrs, stages, StreamEnd::Terminated);
                    assert_eq!(
                        out, reference,
                        "lanes(L={lanes}) vs unified: K={} snr={snr} seed={seed:#x}",
                        spec.k
                    );
                    let mt = LanesMtEngine::new(
                        LanesEngine::new(spec.clone(), *geo, ptb, lanes),
                        Arc::clone(&pool),
                    );
                    let out_mt = run(&mt, &llrs, stages, StreamEnd::Terminated);
                    assert_eq!(
                        out_mt, reference,
                        "lanes-mt(L={lanes}) vs unified: K={} snr={snr} seed={seed:#x}",
                        spec.k
                    );
                }
            }
        }
    }
}

#[test]
fn truncated_streams_match_too() {
    // Truncated end: the final traceback starts at the per-lane argmax
    // instead of state 0 — a different code path worth pinning.
    let spec = CodeSpec::standard_k7();
    let geo = FrameGeometry::new(96, 20, 30);
    let ptb = ParallelTraceback::new(24, 30, StartPolicy::StoredArgmax);
    let mut rng = Rng64::seeded(0x7A6C);
    let mut bits = vec![0u8; 96 * 9 - 11];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let stages = bits.len();
    let ch = AwgnChannel::new(3.0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());

    let unified = TiledEngine::new(spec.clone(), geo, TracebackMode::Parallel(ptb));
    let reference = run(&unified, &llrs, stages, StreamEnd::Truncated);
    let e = LanesEngine::new(spec.clone(), geo, ptb, 64);
    assert_eq!(run(&e, &llrs, stages, StreamEnd::Truncated), reference);
}
