//! Acceptance suite for the observability layer: a traced 2^16-stage
//! block-parallel decode must export valid Chrome trace-event JSONL
//! with per-group `lane_group` spans, and the per-stage clocks (ACS,
//! traceback) must be nonzero and consistent with the wall clock.
//!
//! This file holds exactly one test on purpose: the trace ring buffer
//! and the enable flags are process-global, so no other test may share
//! the binary without stealing events.

use std::collections::HashMap;

use viterbi::channel::Rng64;
use viterbi::code::CodeSpec;
use viterbi::obs::{self, ObsConfig, TracePhase};
use viterbi::util::json::Json;
use viterbi::viterbi::{BlocksEngine, DecodeRequest, Engine, StreamEnd};

#[test]
fn traced_blocks_decode_exports_valid_chrome_jsonl() {
    ObsConfig::enabled().apply();
    let _ = obs::drain_trace();

    let stages = 1usize << 16;
    let spec = CodeSpec::standard_k7();
    let beta = spec.beta as usize;
    let mut rng = Rng64::seeded(0x0B5);
    let llrs: Vec<f32> =
        (0..stages * beta).map(|_| (rng.uniform() as f32 - 0.5) * 8.0).collect();
    let engine = BlocksEngine::new(spec, 32);

    let t0 = std::time::Instant::now();
    obs::begin_with("decode", &[("stages", stages as f64)]);
    let out = engine
        .decode(&DecodeRequest::hard(&llrs, stages, StreamEnd::Truncated))
        .expect("blocks decode");
    obs::end("decode");
    let wall_ns = t0.elapsed().as_nanos() as u64;
    assert_eq!(out.bits.len(), stages);

    // Stage clocks: present, nonzero, and within 2x the wall clock
    // (each stage is timed at most once per decode pass, so their sum
    // can never exceed 2x wall even with clock-read jitter).
    let stage = out.stats.stage_timings.expect("stage timings enabled");
    assert!(stage.acs_ns > 0, "{stage:?}");
    assert!(stage.traceback_ns > 0, "{stage:?}");
    assert!(
        stage.acs_ns + stage.traceback_ns <= wall_ns.saturating_mul(2),
        "acs {} + traceback {} vs wall {wall_ns}",
        stage.acs_ns,
        stage.traceback_ns
    );

    let events = obs::drain_trace();
    assert!(!events.is_empty());

    // Balanced, properly nested spans per thread, and the block engine
    // emitted at least one lane_group span carrying its lane count.
    let mut open: HashMap<u64, Vec<&str>> = HashMap::new();
    let mut lane_groups = 0usize;
    for ev in &events {
        match ev.phase {
            TracePhase::Begin => {
                if ev.name == "lane_group" {
                    lane_groups += 1;
                    assert!(
                        ev.args.iter().any(|(k, v)| *k == "lanes" && *v >= 1.0),
                        "{ev:?}"
                    );
                }
                open.entry(ev.tid).or_default().push(ev.name);
            }
            TracePhase::End => {
                assert_eq!(open.entry(ev.tid).or_default().pop(), Some(ev.name), "{ev:?}");
            }
            TracePhase::Counter => {}
        }
    }
    assert!(open.values().all(Vec::is_empty), "unclosed spans: {open:?}");
    assert!(lane_groups >= 1, "no lane_group spans in {} events", events.len());

    // The Chrome JSONL export: one well-formed object per line with
    // the required keys; the block decode is single-threaded, so the
    // buffer order gives monotone timestamps.
    let text = obs::export_chrome_jsonl(&events);
    let mut last_ts = f64::NEG_INFINITY;
    let mut lines = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).expect("well-formed trace line");
        assert!(j.get("name").and_then(Json::as_str).is_some());
        let ph = j.get("ph").and_then(Json::as_str).expect("phase");
        assert!(matches!(ph, "B" | "E" | "C"), "{ph}");
        let ts = j.get("ts").and_then(Json::as_f64).expect("timestamp");
        assert!(ts >= last_ts, "timestamps must be monotone");
        last_ts = ts;
        assert!(j.get("tid").and_then(Json::as_f64).is_some());
        lines += 1;
    }
    assert_eq!(lines, events.len());
}
