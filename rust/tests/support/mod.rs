//! Shared integration-test support: the exhaustive brute-force
//! maximum-likelihood reference decoder for tail-biting codes.
//!
//! The oracle enumerates **every** possible input block (all `2^n`
//! messages, tractable for `n ≤ 20`, used at `n ≤ 12`), encodes each
//! circularly, and picks the codeword with the maximum correlation
//! against the received LLRs — the minimum-distance decision by
//! construction, with no trellis machinery shared with the decoders
//! under test. It is the ground truth the WAVA parity suite
//! (`rust/tests/wava_parity.rs`) gates on, and a reusable oracle for
//! any engine on short blocks.

use viterbi::code::{encode, CodeSpec, Termination};

/// Correlation score of a codeword against received LLRs under the
/// decoders' branch-metric convention: a positive LLR favours coded
/// bit 0, so `score = Σ (coded_i == 0 ? +llr_i : −llr_i)`. Maximizing
/// this is exactly minimizing soft distance. Accumulated in f64 so the
/// oracle's comparisons are not at the mercy of f32 summation order.
pub fn codeword_score(coded: &[u8], llrs: &[f32]) -> f64 {
    debug_assert_eq!(coded.len(), llrs.len());
    coded
        .iter()
        .zip(llrs)
        .map(|(&b, &l)| if b == 0 { l as f64 } else { -(l as f64) })
        .sum()
}

/// Exhaustive brute-force ML decoder for one tail-biting code at one
/// block length: all `2^n` circular codewords are precomputed once so
/// repeated decodes only pay the scoring sweep.
pub struct BruteForceTailBiting {
    spec: CodeSpec,
    n: usize,
    /// codewords[m] = tail-biting encoding of message m (bit i of `m`
    /// is message bit i).
    codewords: Vec<Vec<u8>>,
}

impl BruteForceTailBiting {
    /// Precompute the full circular codebook for `n`-bit messages.
    pub fn new(spec: CodeSpec, n: usize) -> Self {
        assert!(n <= 20, "brute force is exponential in n");
        assert!(n >= (spec.k - 1) as usize, "tail-biting needs n ≥ k−1");
        let codewords = (0u64..(1u64 << n))
            .map(|m| encode(&spec, &message_bits(m, n), Termination::TailBiting))
            .collect();
        BruteForceTailBiting { spec, n, codewords }
    }

    /// True when every message maps to a distinct codeword — the
    /// tail-biting map is injective at this length, so ML decoding is
    /// well defined. (Degenerate (n, K) combinations exist for some
    /// codes; the parity suite asserts this before trusting parity.)
    pub fn is_injective(&self) -> bool {
        let mut seen = std::collections::HashSet::with_capacity(self.codewords.len());
        self.codewords.iter().all(|c| seen.insert(c.clone()))
    }

    /// Decode: return the message whose circular codeword scores
    /// highest against `llrs` (ties break to the lowest message index;
    /// measure-zero on continuous noisy LLRs). Also returns the
    /// winning score for optimality cross-checks.
    pub fn decode_scored(&self, llrs: &[f32]) -> (Vec<u8>, f64) {
        assert_eq!(llrs.len(), self.n * self.spec.beta as usize);
        let mut best_m = 0u64;
        let mut best = f64::NEG_INFINITY;
        for (m, coded) in self.codewords.iter().enumerate() {
            let s = codeword_score(coded, llrs);
            if s > best {
                best = s;
                best_m = m as u64;
            }
        }
        (message_bits(best_m, self.n), best)
    }

    /// Decode, returning the ML message bits only.
    pub fn decode(&self, llrs: &[f32]) -> Vec<u8> {
        self.decode_scored(llrs).0
    }
}

/// Bit i of `m` as message bit i.
pub fn message_bits(m: u64, n: usize) -> Vec<u8> {
    (0..n).map(|i| ((m >> i) & 1) as u8).collect()
}

/// Noiseless BPSK LLRs for a coded bit sequence (±4.0, the convention
/// of the unit suites: positive favours bit 0).
pub fn noiseless_llrs(coded: &[u8]) -> Vec<f32> {
    coded.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect()
}

/// Rotate a message left by `s` positions (bit `s` becomes bit 0) —
/// the circular-shift the tail-biting equivariance property acts by.
pub fn rotate_left<T: Clone>(xs: &[T], s: usize) -> Vec<T> {
    let n = xs.len();
    if n == 0 {
        return Vec::new();
    }
    let s = s % n;
    xs[s..].iter().chain(xs[..s].iter()).cloned().collect()
}
