//! Cross-module integration: the full Fig-8 chain (encode → puncture →
//! BPSK → AWGN → LLR → depuncture → decode) through every engine
//! variant, plus property tests on the code/channel substrates.

use std::sync::Arc;

use viterbi::ber::{measure_point, soft_viterbi_ber, BerConfig, DistanceSpectrum};
use viterbi::channel::{bpsk, llr, AwgnChannel, LlrQuantizer, Rng64};
use viterbi::code::{
    depuncture_llrs, encode, puncture, CodeSpec, PuncturePattern, Termination,
};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::bits::count_bit_errors;
use viterbi::util::check;
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    DecodeRequest, Engine, HardEngine, ParallelEngine, ParallelTraceback, ScalarEngine,
    StartPolicy, StreamEnd, TiledEngine, TracebackMode,
};

/// Decode helper over the request/response engine API.
fn run(e: &dyn Engine, llrs: &[f32], stages: usize, end: StreamEnd) -> Vec<u8> {
    e.decode(&DecodeRequest::hard(llrs, stages, end)).expect("decode").bits
}

fn engines(spec: &CodeSpec) -> Vec<Box<dyn Engine>> {
    vec![
        Box::new(ScalarEngine::new(spec.clone())),
        Box::new(TiledEngine::new(
            spec.clone(),
            FrameGeometry::new(128, 20, 30),
            TracebackMode::FrameSerial,
        )),
        Box::new(TiledEngine::new(
            spec.clone(),
            FrameGeometry::new(256, 20, 45),
            TracebackMode::Parallel(ParallelTraceback::new(32, 45, StartPolicy::StoredArgmax)),
        )),
        Box::new(ParallelEngine::new(
            TiledEngine::new(
                spec.clone(),
                FrameGeometry::new(256, 20, 45),
                TracebackMode::Parallel(ParallelTraceback::new(
                    32,
                    45,
                    StartPolicy::StoredArgmax,
                )),
            ),
            Arc::new(ThreadPool::new(4)),
        )),
    ]
}

#[test]
fn every_engine_survives_the_full_chain() {
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(500);
    let n = 20_000usize;
    let mut msg = vec![0u8; n];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Terminated);
    let ch = AwgnChannel::new(4.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());
    let stages = n + 6;

    for engine in engines(&spec) {
        let out = run(engine.as_ref(), &llrs, stages, StreamEnd::Terminated);
        let errors = count_bit_errors(&out[..n], &msg);
        let ber = errors as f64 / n as f64;
        assert!(
            ber < 3e-4,
            "engine {} BER {ber:.2e} too high at 4 dB",
            engine.name()
        );
    }
}

#[test]
fn punctured_chain_all_rates() {
    let spec = CodeSpec::standard_k7();
    let engine = TiledEngine::new(
        spec.clone(),
        FrameGeometry::new(256, 32, 32),
        TracebackMode::FrameSerial,
    );
    let mut rng = Rng64::seeded(501);
    let n = 30_000usize;
    let mut msg = vec![0u8; n];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Terminated);
    let stages = n + 6;

    let mut bers = Vec::new();
    for label in ["1/2", "2/3", "3/4"] {
        let pat = PuncturePattern::by_label(label).unwrap();
        let tx = puncture(&coded, 2, &pat);
        let ch = AwgnChannel::new(4.5, pat.effective_rate());
        let rx = ch.transmit(&bpsk::modulate(&tx), &mut rng);
        let rx_llrs = llr::llrs_from_samples(&rx, ch.sigma());
        let full = depuncture_llrs(&rx_llrs, 2, &pat, stages);
        let out = run(&engine, &full, stages, StreamEnd::Terminated);
        bers.push(count_bit_errors(&out[..n], &msg) as f64 / n as f64);
    }
    // Monotone degradation with rate (allowing zero-error ties at the
    // strongest rates).
    assert!(bers[0] <= bers[1] + 1e-9, "1/2 {0:?} vs 2/3 {1:?}", bers[0], bers[1]);
    assert!(bers[1] <= bers[2] + 1e-9, "2/3 {0:?} vs 3/4 {1:?}", bers[1], bers[2]);
    assert!(bers[2] < 0.05, "3/4 BER way off: {}", bers[2]);
}

#[test]
fn quantized_llrs_cost_little_at_6bits() {
    let spec = CodeSpec::standard_k7();
    let engine = ScalarEngine::new(spec.clone());
    let mut rng = Rng64::seeded(502);
    let n = 30_000usize;
    let mut msg = vec![0u8; n];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Terminated);
    let ch = AwgnChannel::new(3.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());
    let stages = n + 6;

    let e_float = count_bit_errors(
        &run(&engine, &llrs, stages, StreamEnd::Terminated)[..n],
        &msg,
    );
    let q6 = LlrQuantizer::new(6, 16.0);
    let e_q6 = count_bit_errors(
        &run(&engine, &q6.roundtrip(&llrs), stages, StreamEnd::Terminated)[..n],
        &msg,
    );
    let q2 = LlrQuantizer::new(2, 16.0);
    let e_q2 = count_bit_errors(
        &run(&engine, &q2.roundtrip(&llrs), stages, StreamEnd::Terminated)[..n],
        &msg,
    );
    assert!(
        (e_q6 as f64) <= e_float as f64 * 1.5 + 5.0,
        "6-bit quantization too lossy: {e_q6} vs {e_float}"
    );
    assert!(e_q2 >= e_q6, "2-bit ({e_q2}) should not beat 6-bit ({e_q6})");
}

#[test]
fn harness_matches_direct_loop() {
    // The BerConfig-driven harness and a hand-rolled loop must agree
    // on the same seed-derived channel (consistency of the Fig-8 path).
    let spec = CodeSpec::standard_k7();
    let engine = ScalarEngine::new(spec.clone());
    let cfg = BerConfig {
        block_bits: 4096,
        target_errors: 50,
        max_bits: 300_000,
        seed: 77,
        puncture: None,
    };
    let p = measure_point(&spec, &engine, &cfg, 3.0);
    assert!(p.reliable);
    let bound = soft_viterbi_ber(3.0, 0.5, &DistanceSpectrum::k7_171_133());
    assert!(p.ber <= bound * 2.0, "measured {} vs bound {}", p.ber, bound);
}

#[test]
fn hard_adapter_composes_with_tiled() {
    let spec = CodeSpec::standard_k7();
    let eng = HardEngine::new(TiledEngine::new(
        spec.clone(),
        FrameGeometry::new(128, 20, 30),
        TracebackMode::FrameSerial,
    ));
    let mut rng = Rng64::seeded(503);
    let mut msg = vec![0u8; 5000];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Terminated);
    // 20 scattered hard errors, far apart: correctable.
    let mut rx = coded.clone();
    for i in 0..20 {
        rx[i * 497] ^= 1;
    }
    let out = eng.decode_bits(&rx, msg.len() + 6, StreamEnd::Terminated);
    assert_eq!(&out[..msg.len()], &msg[..]);
}

#[test]
fn property_roundtrip_noiseless_random_geometry() {
    check::forall(
        "noiseless decode is exact for any frame geometry",
        40,
        0xD0_0D,
        |rng| {
            let (f, v1, v2) = check::gen_frame_geometry(rng);
            let f0 = rng.gen_range_usize(1, f.max(2));
            let n = rng.gen_range_usize(50, 1500);
            let seed = rng.next_u64();
            (f, v1, v2.max(18), f0, n, seed)
        },
        |&(f, v1, v2, f0, n, seed)| {
            let spec = CodeSpec::standard_k7();
            let mut rng = Rng64::seeded(seed);
            let mut msg = vec![0u8; n];
            rng.fill_bits(&mut msg);
            let coded = encode(&spec, &msg, Termination::Terminated);
            let llrs: Vec<f32> =
                coded.iter().map(|&b| if b == 0 { 3.0 } else { -3.0 }).collect();
            let engine = TiledEngine::new(
                spec,
                FrameGeometry::new(f, v1, v2),
                TracebackMode::Parallel(ParallelTraceback::new(
                    f0,
                    v2,
                    StartPolicy::StoredArgmax,
                )),
            );
            let out = run(&engine, &llrs, n + 6, StreamEnd::Terminated);
            assert_eq!(&out[..n], &msg[..], "f={f} v1={v1} v2={v2} f0={f0} n={n}");
        },
    );
}

#[test]
fn property_puncture_depuncture_positions() {
    check::forall(
        "depuncture inverts puncture positions",
        100,
        0xD00D2,
        |rng| {
            let label = ["1/2", "2/3", "3/4"][rng.gen_range_usize(0, 3)];
            let stages = rng.gen_range_usize(1, 400);
            (label, stages, rng.next_u64())
        },
        |&(label, stages, seed)| {
            let pat = PuncturePattern::by_label(label).unwrap();
            let mut rng = Rng64::seeded(seed);
            let llrs = check::gen_llrs(&mut rng, viterbi::code::punctured_len(stages, 2, &pat), 4.0);
            let full = depuncture_llrs(&llrs, 2, &pat, stages);
            assert_eq!(full.len(), stages * 2);
            // Every original value appears in order; punctured slots are 0.
            let mut kept: Vec<f32> = Vec::new();
            for t in 0..stages {
                let col = t % pat.period();
                for lane in 0..2 {
                    if pat.keep[lane][col] {
                        kept.push(full[t * 2 + lane]);
                    }
                }
            }
            assert_eq!(kept, llrs);
        },
    );
}

#[test]
fn property_llr_scale_invariance() {
    // Max-metric Viterbi must be invariant to positive LLR scaling.
    check::forall(
        "decoder invariant under positive LLR scaling",
        20,
        0x5CA1E,
        |rng| (rng.next_u64(), 0.25 + rng.uniform() * 10.0),
        |&(seed, scale)| {
            let spec = CodeSpec::standard_k7();
            let engine = ScalarEngine::new(spec.clone());
            let mut rng = Rng64::seeded(seed);
            let mut msg = vec![0u8; 800];
            rng.fill_bits(&mut msg);
            let coded = encode(&spec, &msg, Termination::Terminated);
            let ch = AwgnChannel::new(1.0, 0.5);
            let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
            let llrs = llr::llrs_from_samples(&rx, ch.sigma());
            let scaled: Vec<f32> = llrs.iter().map(|&x| x * scale as f32).collect();
            let a = run(&engine, &llrs, 806, StreamEnd::Terminated);
            let b = run(&engine, &scaled, 806, StreamEnd::Terminated);
            assert_eq!(a, b, "scale {scale}");
        },
    );
}
