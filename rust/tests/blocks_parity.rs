//! Truncation-correctness suite for the overlapped block-parallel
//! single-stream engine (`blocks`).
//!
//! Three claims are pinned here:
//!
//! 1. **Parity with the whole-stream reference** at the calibrated
//!    overlap depth `5·(K−1)`: across K = 3/5/7 and stream lengths
//!    straddling every 1/2/3-block-boundary threshold of the planner,
//!    block decode is bit-identical to the `scalar` whole-stream
//!    decoder (10 dB Eb/N0 — far above the waterfall, so both recover
//!    the transmitted sequence exactly and any disagreement is a real
//!    defect, the same argument `registry_smoke.rs` makes).
//! 2. **Block-count invariance**: splitting the same stream into 1, 2,
//!    4, 8 or 64 blocks never changes the output.
//! 3. **Truncation-depth characterization**: with the overlap depth
//!    swept from `1·(K−1)` to `5·(K−1)` on a seeded noisy stream, the
//!    disagreement against the full-stream decode decays monotonically
//!    (up to ±2 bits of counting jitter) and is negligible at the
//!    calibrated depth — the planner's `5·(K−1)` rule, measured.

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::{calibrated_depth, choose_blocks, MAX_BLOCKS};
use viterbi::util::bits::count_bit_errors;
use viterbi::util::check;
use viterbi::viterbi::{
    BlocksEngine, DecodeRequest, Engine, ScalarEngine, StreamEnd,
};

fn run(e: &dyn Engine, llrs: &[f32], stages: usize, end: StreamEnd) -> Vec<u8> {
    e.decode(&DecodeRequest::hard(llrs, stages, end)).expect("decode").bits
}

/// Noisy terminated workload: `n` info bits of `spec` at `ebn0` dB.
fn workload(spec: &CodeSpec, n: usize, ebn0: f64, seed: u64) -> (Vec<u8>, Vec<f32>, usize) {
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(spec, &bits, Termination::Terminated);
    let stages = n + (spec.k as usize - 1);
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    (bits, llr::llrs_from_samples(&rx, ch.sigma()), stages)
}

#[test]
fn blocks_match_whole_stream_reference_across_boundary_straddles() {
    // The planner's block count steps at multiples of its minimum kept
    // region (`choose_blocks`); lengths one stage either side of the
    // 1-, 2- and 3-block thresholds exercise every straddle, including
    // the degenerate "stream shorter than one block" case.
    for k in [3u32, 5, 7] {
        let spec = CodeSpec::for_constraint(k);
        let depth = calibrated_depth(k);
        // Reverse-engineer the planner's threshold: the smallest
        // stream that still gets b blocks has b·min_kept stages.
        let min_kept = (4 * depth).max(32);
        let reference = ScalarEngine::new(spec.clone());
        for b in [1usize, 2, 3] {
            for delta in [-1isize, 0, 1] {
                // Thresholds are in *stages*; place the stream length
                // (info bits + termination tail) one stage either side.
                let n = ((min_kept * b) as isize + delta) as usize - (k as usize - 1);
                let seed = 0xB10C_0100 ^ ((k as u64) << 8) ^ ((b as u64) << 16)
                    ^ ((delta + 1) as u64);
                let (bits, llrs, stages) = workload(&spec, n, 10.0, seed);
                let e = BlocksEngine::new(spec.clone(), 32);
                let out = run(&e, &llrs, stages, StreamEnd::Terminated);
                let want = run(&reference, &llrs, stages, StreamEnd::Terminated);
                assert_eq!(
                    out, want,
                    "blocks vs scalar: K={k} n={n} ({} blocks planned)",
                    e.plan_for(stages).spans.len()
                );
                assert_eq!(&out[..n], &bits[..], "K={k} n={n}: decode not error-free");
            }
        }
    }
}

#[test]
fn long_multi_block_streams_match_the_reference() {
    // A comfortably multi-block stream per K (the straddle test above
    // stays near the thresholds where plans are small).
    for k in [3u32, 5, 7] {
        let spec = CodeSpec::for_constraint(k);
        let depth = calibrated_depth(k);
        let n = (4 * depth).max(32) * 3 + 17;
        let (bits, llrs, stages) = workload(&spec, n, 10.0, 0xB10C_0200 ^ k as u64);
        let e = BlocksEngine::new(spec.clone(), 32);
        let planned = e.plan_for(stages).spans.len();
        assert_eq!(planned, choose_blocks(stages, depth, MAX_BLOCKS), "K={k}");
        assert!(planned >= 3, "K={k}: expected a multi-block plan, got {planned}");
        let out = run(&e, &llrs, stages, StreamEnd::Terminated);
        let want = run(&ScalarEngine::new(spec.clone()), &llrs, stages, StreamEnd::Terminated);
        assert_eq!(out, want, "K={k} n={n}");
        assert_eq!(&out[..n], &bits[..], "K={k} n={n}");
    }
}

#[test]
fn truncated_streams_match_the_reference_too() {
    // Truncated end: the final traceback starts at the stream-end
    // argmax instead of the terminated state — a different code path
    // for the last block.
    let spec = CodeSpec::standard_k7();
    let n = 2000usize;
    let mut rng = Rng64::seeded(0xB10C_0300);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let ch = AwgnChannel::new(10.0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());
    let e = BlocksEngine::new(spec.clone(), 32);
    let out = run(&e, &llrs, n, StreamEnd::Truncated);
    let want = run(&ScalarEngine::new(spec), &llrs, n, StreamEnd::Truncated);
    assert_eq!(out, want);
}

#[test]
fn output_is_invariant_across_block_counts() {
    // Splitting one stream into 1, 2, 4, 8 or 64 blocks is a pure
    // execution-layout change at sufficient overlap depth: the output
    // must not move. The 1-block plan is the whole stream (no
    // boundaries at all), so equality against it also re-proves the
    // boundary handling of every wider split.
    let spec = CodeSpec::standard_k7();
    let depth = calibrated_depth(7);
    let (bits, llrs, stages) = workload(&spec, 6000, 10.0, 0xB10C_0400);
    let single = run(
        &BlocksEngine::with_block_count(spec.clone(), depth, 1, 32),
        &llrs,
        stages,
        StreamEnd::Terminated,
    );
    assert_eq!(&single[..bits.len()], &bits[..]);
    for b in [2usize, 4, 8, 64] {
        let e = BlocksEngine::with_block_count(spec.clone(), depth, b, 32);
        assert_eq!(e.plan_for(stages).spans.len(), b, "B={b}");
        let out = run(&e, &llrs, stages, StreamEnd::Terminated);
        assert_eq!(out, single, "B={b} changed the decoded stream");
    }
}

#[test]
fn property_block_count_invariance_on_random_lengths() {
    // Property form: random stream lengths (including shorter than one
    // block) and the full block-count ladder, each case a fresh
    // high-SNR workload. Failures replay by the printed case seed.
    check::forall(
        "block count invariance",
        12,
        0xB10C_0500,
        |rng| rng.gen_range_usize(40, 3000),
        |&n| {
            let spec = CodeSpec::standard_k7();
            let depth = calibrated_depth(7);
            let (_bits, llrs, stages) = workload(&spec, n, 10.0, 0xB10C_0501 ^ n as u64);
            let single = run(
                &BlocksEngine::with_block_count(spec.clone(), depth, 1, 32),
                &llrs,
                stages,
                StreamEnd::Terminated,
            );
            for b in [2usize, 4, 8, 64] {
                let e = BlocksEngine::with_block_count(spec.clone(), depth, b, 32);
                let out = run(&e, &llrs, stages, StreamEnd::Terminated);
                assert_eq!(out, single, "n={n} B={b}");
            }
        },
    );
}

#[test]
fn truncation_error_decays_with_overlap_depth() {
    // The 5·(K−1) rule, measured: force a 64-block split of a long
    // noisy K=5 stream and sweep the overlap depth m·(K−1) for
    // m = 1..=5, counting disagreements against the full-stream scalar
    // decode of the same realization. Shallow overlap leaves the
    // survivors unmerged at block boundaries (large disagreement);
    // each added (K−1) of depth shrinks it; at the calibrated depth
    // the artifact is negligible.
    let spec = CodeSpec::standard_k5();
    let reference = ScalarEngine::new(spec.clone());
    let mut disagreements = [0usize; 5];
    for seed in [0xB10C_0600u64, 0xB10C_0601] {
        let (_bits, llrs, stages) = workload(&spec, 16380, 3.0, seed);
        let want = run(&reference, &llrs, stages, StreamEnd::Terminated);
        for m in 1..=5usize {
            let depth = m * (spec.k as usize - 1);
            let e = BlocksEngine::with_block_count(spec.clone(), depth, 64, 32);
            let out = run(&e, &llrs, stages, StreamEnd::Terminated);
            disagreements[m - 1] += count_bit_errors(&out, &want);
        }
    }
    // Depth 1·(K−1) is the minimum merge distance: the 126 block
    // boundaries leave plenty of truncation artifacts behind.
    assert!(
        disagreements[0] >= 10,
        "shallow overlap produced implausibly few artifacts: {disagreements:?}"
    );
    // Monotone decay, up to ±2 bits of counting jitter in the tail.
    for m in 1..5 {
        assert!(
            disagreements[m] <= disagreements[m - 1] + 2,
            "depth {}·(K−1) disagrees more than {}·(K−1): {disagreements:?}",
            m + 1,
            m
        );
    }
    // The calibrated depth all but eliminates the artifact.
    assert!(
        disagreements[4] * 5 <= disagreements[0],
        "5·(K−1) overlap left too many artifacts: {disagreements:?}"
    );
}

#[test]
fn calibrated_depth_matches_full_stream_decode_exactly() {
    // "Matches full-stream decode at 5·K" in its strong, bit-exact
    // form, in a regime where the truncation-artifact probability is
    // negligible: a long K=7 stream at 8 dB, auto block planning
    // (64 blocks for this length).
    let spec = CodeSpec::standard_k7();
    let (bits, llrs, stages) = workload(&spec, 20_000, 8.0, 0xB10C_0700);
    let e = BlocksEngine::new(spec.clone(), 32);
    assert_eq!(e.plan_for(stages).spans.len(), 64);
    let out = run(&e, &llrs, stages, StreamEnd::Terminated);
    let want = run(&ScalarEngine::new(spec), &llrs, stages, StreamEnd::Terminated);
    assert_eq!(out, want);
    assert_eq!(&out[..bits.len()], &bits[..]);
}
