//! Serve-gateway end-to-end tests over real loopback sockets: wire
//! round-trips must be bit-exact against the in-process coordinator,
//! overload must shed (never queue unboundedly), expired deadlines
//! must be reaped, and the stress harness must complete cleanly under
//! light load.

use std::time::Duration;

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::coordinator::{BackendSpec, BatchPolicy, DecodeServer, ServerConfig};
use viterbi::frames::plan::FrameGeometry;
use viterbi::gateway::{stress, ClientError, Gateway, GatewayClient, GatewayConfig, StressConfig};
use viterbi::viterbi::{OutputMode, StreamEnd};

fn small_geo() -> FrameGeometry {
    FrameGeometry::new(32, 8, 12)
}

/// Encode `n` random message bits with `term` and push them through a
/// seeded AWGN channel — both decode paths get the identical LLRs.
fn noisy_llrs(
    rng: &mut Rng64,
    spec: &CodeSpec,
    n: usize,
    term: Termination,
) -> Vec<f32> {
    let mut msg = vec![0u8; n];
    rng.fill_bits(&mut msg);
    let coded = encode(spec, &msg, term);
    let ch = AwgnChannel::new(4.0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&coded), rng);
    llr::llrs_from_samples(&rx, ch.sigma())
}

#[test]
fn gateway_is_bit_exact_against_in_process_server_across_shards() {
    let spec = CodeSpec::standard_k5();
    let geo = small_geo();
    let mut gw =
        Gateway::start(GatewayConfig::loopback(spec.clone(), geo, 3)).expect("gateway");
    let reference = DecodeServer::start(ServerConfig {
        backend: BackendSpec::Native {
            spec: spec.clone(),
            geo,
            f0: Some((geo.f / 4).max(1)),
        },
        batch: BatchPolicy::default(),
        high_watermark: 4096,
        low_watermark: 1024,
    })
    .expect("reference server");
    let mut client = GatewayClient::connect(&gw.local_addr().to_string(), spec.clone())
        .expect("connect");

    // Uniform hard traffic (terminated and truncated), ragged lengths,
    // soft output, and tail-biting — every shard class gets exercised.
    let cases: &[(usize, Termination, StreamEnd, OutputMode)] = &[
        (32, Termination::Truncated, StreamEnd::Truncated, OutputMode::Hard),
        (64, Termination::Truncated, StreamEnd::Truncated, OutputMode::Hard),
        (28, Termination::Terminated, StreamEnd::Terminated, OutputMode::Hard),
        (17, Termination::Truncated, StreamEnd::Truncated, OutputMode::Hard),
        (45, Termination::Truncated, StreamEnd::Truncated, OutputMode::Soft),
        (48, Termination::TailBiting, StreamEnd::TailBiting, OutputMode::Hard),
        (100, Termination::Truncated, StreamEnd::Truncated, OutputMode::Soft),
        (33, Termination::TailBiting, StreamEnd::TailBiting, OutputMode::Hard),
    ];
    let mut rng = Rng64::seeded(0x6A7E_11);
    let mut uniform = 0u64;
    let mut specialty = 0u64;
    for &(n, term, end, output) in cases {
        let llrs = noisy_llrs(&mut rng, &spec, n, term);
        let stages = llrs.len() / spec.beta as usize;
        if output == OutputMode::Hard && end != StreamEnd::TailBiting && stages % geo.f == 0
        {
            uniform += 1;
        } else {
            specialty += 1;
        }
        let got = client
            .decode(llrs.clone(), end, output, None)
            .unwrap_or_else(|e| panic!("gateway decode ({n} bits, {end:?}, {output:?}): {e}"));
        let want = reference
            .decode_blocking_with(llrs, end, output)
            .unwrap_or_else(|e| panic!("reference decode ({n} bits, {end:?}, {output:?}): {e}"));
        assert_eq!(got.bits, want.bits, "hard bits differ ({n} bits, {end:?}, {output:?})");
        assert_eq!(got.soft, want.soft, "soft values differ ({n} bits, {end:?}, {output:?})");
        assert!(got.latency_ns > 0, "gateway latency must be measured");
    }

    // Shard affinity: uniform lane-friendly traffic pinned to shard 0,
    // everything else round-robined over the specialty shards.
    let routed = gw.routed_counts();
    assert_eq!(routed.len(), 3);
    assert_eq!(routed[0], uniform, "uniform traffic must pin to shard 0: {routed:?}");
    assert_eq!(routed[1] + routed[2], specialty, "specialty traffic spread: {routed:?}");
    assert!(routed[1] > 0 && routed[2] > 0, "round-robin must use every shard: {routed:?}");
    assert_eq!(gw.shed_count(), 0);
    gw.stop();
}

#[test]
fn gateway_sheds_under_overload_and_keeps_serving() {
    let spec = CodeSpec::standard_k5();
    let geo = small_geo();
    let mut cfg = GatewayConfig::loopback(spec.clone(), geo, 1);
    // A tiny gate plus a slow batcher: admitted frames linger in the
    // batch window, so a pipelined burst at far more than capacity
    // must trip the high watermark.
    cfg.high_watermark = 4;
    cfg.low_watermark = 1;
    cfg.batch = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(50) };
    let mut gw = Gateway::start(cfg).expect("gateway");
    let mut client =
        GatewayClient::connect(&gw.local_addr().to_string(), spec.clone()).expect("connect");

    let mut rng = Rng64::seeded(0x0E21);
    let llrs = noisy_llrs(&mut rng, &spec, 32, Termination::Truncated);
    let burst = 64usize;
    for _ in 0..burst {
        client
            .submit(llrs.clone(), StreamEnd::Truncated, OutputMode::Hard, None)
            .expect("submit");
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for _ in 0..burst {
        match client.recv() {
            Ok(resp) => {
                ok += 1;
                assert!(!resp.bits.is_empty());
            }
            Err(ClientError::Overloaded { retry_after_ms }) => {
                shed += 1;
                assert!(retry_after_ms >= 1, "shed replies must carry a retry hint");
            }
            Err(e) => panic!("only overload errors are acceptable under burst: {e}"),
        }
    }
    assert!(ok > 0, "the gate must admit up to the high watermark");
    assert!(shed > 0, "a {burst}-deep burst over a 4-frame gate must shed");
    assert_eq!(gw.shed_count(), shed as u64, "client and gateway shed counts agree");

    // Once the burst drains the gate falls below the low watermark and
    // the same connection is served again.
    let resp = client
        .decode(llrs, StreamEnd::Truncated, OutputMode::Hard, None)
        .expect("gateway must recover after shedding");
    assert!(!resp.bits.is_empty());
    gw.stop();
}

#[test]
fn expired_deadline_is_shed_not_decoded() {
    let spec = CodeSpec::standard_k5();
    let geo = small_geo();
    let mut cfg = GatewayConfig::loopback(spec.clone(), geo, 1);
    // A long batch window guarantees a microsecond deadline expires
    // while the job sits in the queue.
    cfg.batch = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(40) };
    let mut gw = Gateway::start(cfg).expect("gateway");
    let mut client =
        GatewayClient::connect(&gw.local_addr().to_string(), spec.clone()).expect("connect");
    let mut rng = Rng64::seeded(0xDEAD_11);
    let llrs = noisy_llrs(&mut rng, &spec, 40, Termination::Truncated);

    match client.decode(
        llrs.clone(),
        StreamEnd::Truncated,
        OutputMode::Hard,
        Some(Duration::from_micros(50)),
    ) {
        Err(ClientError::Overloaded { .. }) => {}
        other => panic!("a 50µs deadline under a 40ms batch window must shed, got {other:?}"),
    }
    assert!(gw.shed_count() >= 1);

    // Without a deadline the same stream decodes fine.
    let resp = client
        .decode(llrs, StreamEnd::Truncated, OutputMode::Hard, None)
        .expect("undeadlined request succeeds");
    assert!(!resp.bits.is_empty());
    gw.stop();
}

#[test]
fn malformed_bytes_get_a_typed_wire_refusal_then_hangup() {
    use std::io::Write as _;

    use viterbi::gateway::wire::{read_frame, WireError};
    use viterbi::gateway::WireFrame;

    let spec = CodeSpec::standard_k5();
    let mut gw =
        Gateway::start(GatewayConfig::loopback(spec, small_geo(), 1)).expect("gateway");
    let mut s = std::net::TcpStream::connect(gw.local_addr()).expect("connect");
    s.write_all(b"NOPE\x01\x01\x00\x00\x00\x00").expect("write garbage");
    match read_frame(&mut s) {
        Ok(WireFrame::Error(e)) => {
            assert_eq!(e.kind, "wire");
            assert_eq!(e.retry_after_ms, 0);
        }
        other => panic!("expected a typed wire refusal, got {other:?}"),
    }
    // After a framing error the stream is out of sync; the gateway
    // hangs up rather than guessing at resynchronisation.
    match read_frame(&mut s) {
        Err(WireError::Eof) => {}
        other => panic!("expected the gateway to hang up, got {other:?}"),
    }
    gw.stop();
}

#[test]
fn wrong_code_parameters_are_refused_with_context() {
    let spec = CodeSpec::standard_k5();
    let mut gw =
        Gateway::start(GatewayConfig::loopback(spec, small_geo(), 1)).expect("gateway");
    // A K=7 client against a K=5 gateway.
    let wrong = CodeSpec::standard_k7();
    let mut client =
        GatewayClient::connect(&gw.local_addr().to_string(), wrong).expect("connect");
    match client.decode(vec![1.0; 64], StreamEnd::Truncated, OutputMode::Hard, None) {
        Err(ClientError::Remote { kind, message }) => {
            assert_eq!(kind, "wire");
            assert!(message.contains("K=5"), "refusal names the served code: {message}");
        }
        other => panic!("expected a typed refusal, got {other:?}"),
    }
    gw.stop();
}

#[test]
fn stress_harness_light_load_completes_cleanly() {
    let spec = CodeSpec::standard_k5();
    let mut gw =
        Gateway::start(GatewayConfig::loopback(spec, small_geo(), 2)).expect("gateway");
    let cfg = StressConfig {
        requests: 40,
        rate_hz: 0.0,
        connections: 2,
        deadline: None,
        ebn0_db: 4.0,
        seed: 0x5EED,
    };
    let report = stress::run(&cfg, &gw);
    assert_eq!(report.submitted, 40);
    assert_eq!(
        report.completed + report.shed + report.errors,
        report.submitted,
        "every request must be accounted for"
    );
    assert_eq!(report.errors, 0, "light load must not produce hard errors");
    assert_eq!(report.shed, 0, "default watermarks must absorb 40 requests");
    assert!(report.completed > 0 && report.p50_ns > 0 && report.p99_ns >= report.p50_ns);

    let json = format!("{}", stress::report_json(&report, &gw));
    assert!(json.contains("viterbi-stress/1"), "schema tag missing: {json}");
    assert!(json.contains("\"shards\""), "per-shard dispatch missing: {json}");
    assert!(json.contains("\"shed\""), "shed counter missing: {json}");
    gw.stop();
}
