//! Coordinator property and stress tests: chunker/reassembler
//! roundtrips under random geometries, batcher conservation under
//! interleavings, and server stress with mixed stream lengths.

use std::sync::Arc;
use std::time::{Duration, Instant};

use viterbi::channel::Rng64;
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::coordinator::{
    BackendSpec, BatchPolicy, Batcher, Chunker, DecodeServer, FrameJob, Reassembler,
    ServerConfig,
};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::check;
use viterbi::viterbi::StreamEnd;

#[test]
fn property_chunker_blocks_reconstruct_stream() {
    // Every stream LLR must appear in at least one frame block at the
    // right in-block position; padding must be exactly the out-of-range
    // stages.
    check::forall(
        "chunker covers the stream with correct offsets",
        60,
        0xC0DE,
        |rng| {
            let (f, v1, v2) = check::gen_frame_geometry(rng);
            let stages = rng.gen_range_usize(1, 800);
            (f, v1, v2, stages, rng.next_u64())
        },
        |&(f, v1, v2, stages, seed)| {
            let spec = CodeSpec::standard_k5();
            let geo = FrameGeometry::new(f, v1, v2);
            let chunker = Chunker::new(spec, geo);
            let mut rng = Rng64::seeded(seed);
            // Unique nonzero values so positions are identifiable.
            let llrs: Vec<f32> = (0..stages * 2).map(|i| i as f32 + 1.0).collect();
            let _ = rng.next_u64();
            let req = viterbi::coordinator::DecodeRequest::new(
                1,
                llrs.clone(),
                2,
                StreamEnd::Truncated,
            );
            let jobs = chunker.chunk(&req);
            assert_eq!(jobs.len(), chunker.frame_count(stages));
            for job in &jobs {
                let start = job.frame_index as isize * f as isize - v1 as isize;
                for row in 0..geo.span() {
                    let t = start + row as isize;
                    let got = &job.llr_block[row * 2..row * 2 + 2];
                    if t >= 0 && (t as usize) < stages {
                        let src = t as usize * 2;
                        assert_eq!(got, &llrs[src..src + 2], "frame {} row {row}", job.frame_index);
                    } else {
                        assert_eq!(got, &[0.0, 0.0], "padding at frame {} row {row}", job.frame_index);
                    }
                }
            }
            // Decoded regions tile the stream.
            let covered: usize = jobs.len() * f;
            assert!(covered >= stages);
        },
    );
}

#[test]
fn property_reassembler_any_completion_order() {
    check::forall(
        "reassembler completes under any frame arrival order",
        60,
        0xA55E,
        |rng| {
            let frames = rng.gen_range_usize(1, 24);
            let f = rng.gen_range_usize(1, 64);
            let stages = rng.gen_range_usize((frames - 1) * f + 1, frames * f + 1);
            // A random arrival permutation.
            let mut order: Vec<usize> = (0..frames).collect();
            for i in (1..frames).rev() {
                let j = rng.gen_range_usize(0, i + 1);
                order.swap(i, j);
            }
            (frames, f, stages, order)
        },
        |(frames, f, stages, order)| {
            let mut r = Reassembler::new();
            r.expect(9, *frames, *stages, *f, Instant::now(), false);
            let mut resp = None;
            for (k, &idx) in order.iter().enumerate() {
                let fr = viterbi::coordinator::FrameResult {
                    request_id: 9,
                    frame_index: idx,
                    bits: vec![(idx % 2) as u8; *f],
                    soft: None,
                };
                let got = r.accept(fr);
                if k + 1 < order.len() {
                    assert!(got.is_none(), "completed early");
                } else {
                    resp = got;
                }
            }
            let resp = resp.expect("must complete on last frame");
            assert_eq!(resp.bits.len(), *stages);
            for (t, &b) in resp.bits.iter().enumerate() {
                assert_eq!(b, ((t / f) % 2) as u8, "bit {t}");
            }
        },
    );
}

#[test]
fn property_batcher_respects_fifo_and_bounds_under_deadline_interleaving() {
    check::forall(
        "batcher FIFO under mixed push/deadline",
        60,
        0xBA7C2,
        |rng| {
            let max_batch = rng.gen_range_usize(1, 10);
            let ops = rng.gen_range_usize(1, 120);
            let plan: Vec<bool> = (0..ops).map(|_| rng.gen_range_usize(0, 4) == 0).collect();
            (max_batch, plan)
        },
        |(max_batch, plan)| {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: *max_batch,
                max_wait: Duration::from_millis(0), // every poll flushes
            });
            let mut emitted = Vec::new();
            let mut pushed = 0usize;
            for &do_poll in plan {
                if do_poll {
                    if let Some(batch) = b.poll_deadline(Instant::now()) {
                        emitted.extend(batch.jobs.iter().map(|j| j.frame_index));
                    }
                } else {
                    let job = FrameJob {
                        request_id: 1,
                        frame_index: pushed,
                        llr_block: Vec::new(),
                        pin_state0: false,
                        output: viterbi::viterbi::OutputMode::Hard,
                        tail_biting: false,
                        block_stream: false,
                        submitted_at: Instant::now(),
                        deadline: None,
                    };
                    pushed += 1;
                    if let Some(batch) = b.push(job) {
                        assert!(batch.jobs.len() <= *max_batch);
                        emitted.extend(batch.jobs.iter().map(|j| j.frame_index));
                    }
                }
            }
            for batch in b.flush_all() {
                emitted.extend(batch.jobs.iter().map(|j| j.frame_index));
            }
            assert_eq!(emitted, (0..pushed).collect::<Vec<_>>());
        },
    );
}

#[test]
fn block_parallel_matches_sequential_chunk_reassembly() {
    // The same noiseless stream decoded two ways through the worker —
    // as one block-parallel whole-stream job and as sequential
    // overlap-chunked frames — must reassemble to the same message,
    // for ragged lengths including a stream shorter than one
    // overlapped block (where the block planner degenerates to a
    // single whole-stream block).
    let spec = CodeSpec::standard_k5();
    let geo = FrameGeometry::new(64, 12, 20);
    let mut decoder = BackendSpec::Native { spec: spec.clone(), geo, f0: Some(16) }
        .build()
        .unwrap();
    let chunker = Chunker::new(spec.clone(), geo);
    let mut rng = Rng64::seeded(0xB10C);
    for n in [37usize, 64, 100, 333, 1000, 4097] {
        let mut msg = vec![0u8; n];
        rng.fill_bits(&mut msg);
        let enc = encode(&spec, &msg, Termination::Truncated);
        let llrs: Vec<f32> =
            enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();

        // Sequential chunked route: the chunker's overlapped frames.
        let req =
            viterbi::coordinator::DecodeRequest::new(1, llrs.clone(), 2, StreamEnd::Truncated);
        let jobs = chunker.chunk(&req);
        let results = decoder.decode_batch(&jobs).unwrap();
        let mut r = Reassembler::new();
        r.expect(1, jobs.len(), n, geo.f, Instant::now(), false);
        let mut chunked = None;
        for fr in results {
            chunked = r.accept(fr);
        }
        let chunked = chunked.expect("chunked reassembly must complete").bits;

        // Block-parallel route: one whole-stream job, reassembled with
        // the whole-stream frame length the server uses for such jobs.
        let job = FrameJob {
            request_id: 2,
            frame_index: 0,
            llr_block: llrs.clone(),
            pin_state0: true,
            output: viterbi::viterbi::OutputMode::Hard,
            tail_biting: false,
            block_stream: true,
            submitted_at: Instant::now(),
            deadline: None,
        };
        let results = decoder.decode_batch(&[job]).unwrap();
        assert_eq!(results.len(), 1);
        let mut r = Reassembler::new();
        r.expect(2, 1, n, n, Instant::now(), false);
        let blocked = r
            .accept(results.into_iter().next().unwrap())
            .expect("a single whole-stream frame completes the request")
            .bits;

        // Noiseless, every wrong path pays at least one branch error,
        // so the block route is exact on every bit; the chunked
        // route's last frame is right-padded with neutral zero LLRs,
        // so only its trailing v2 stages may tie-break differently.
        assert_eq!(blocked, msg, "block route n={n}");
        assert_eq!(chunked.len(), n);
        let head = n.saturating_sub(geo.v2);
        assert_eq!(&chunked[..head], &msg[..head], "chunked route n={n}");
        assert_eq!(&blocked[..head], &chunked[..head], "routes diverge n={n}");
    }
}

#[test]
fn server_stress_mixed_lengths_and_rejection() {
    let server = Arc::new(
        DecodeServer::start(ServerConfig {
            backend: BackendSpec::Native {
                spec: CodeSpec::standard_k5(),
                geo: FrameGeometry::new(32, 8, 12),
                f0: Some(8),
            },
            batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
            high_watermark: 512,
            low_watermark: 128,
        })
        .unwrap(),
    );
    let spec = CodeSpec::standard_k5();
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let server = Arc::clone(&server);
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng64::seeded(900 + t);
            for i in 0..20usize {
                let n = 1 + ((t as usize * 31 + i * 57) % 300);
                let mut msg = vec![0u8; n];
                rng.fill_bits(&mut msg);
                let enc = encode(&spec, &msg, Termination::Truncated);
                let llrs: Vec<f32> =
                    enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
                let resp = server.decode_blocking(llrs, StreamEnd::Truncated).unwrap();
                assert_eq!(resp.bits.len(), n);
                // Noiseless: all but the trailing (no right context for
                // the final stages of truncated streams) bits match.
                let check_len = n.saturating_sub(8);
                assert_eq!(&resp.bits[..check_len], &msg[..check_len], "t={t} i={i} n={n}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.responses, 120);
    assert_eq!(server.in_flight_frames(), 0);
}

#[test]
fn try_submit_rejects_when_saturated() {
    // A tiny watermark + a big request forces rejection.
    let server = DecodeServer::start(ServerConfig {
        backend: BackendSpec::Native {
            spec: CodeSpec::standard_k5(),
            geo: FrameGeometry::new(32, 8, 12),
            f0: None,
        },
        batch: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) },
        high_watermark: 4,
        low_watermark: 1,
    })
    .unwrap();
    // 10 frames > high watermark of 4 → immediate rejection.
    let llrs = vec![0.5f32; 32 * 10 * 2];
    assert!(server.try_submit(llrs, StreamEnd::Truncated).is_none());
    assert_eq!(server.metrics().rejected, 1);
    // A 1-frame request is accepted and completes.
    let llrs = vec![0.5f32; 32 * 2];
    let id = server.try_submit(llrs, StreamEnd::Truncated).expect("small request fits");
    let resp = server.wait(id).unwrap();
    assert_eq!(resp.bits.len(), 32);
}
