//! WAVA correctness suite, gated by CI (`scripts/check_wava.sh`):
//!
//! * **exhaustive brute-force ML parity** — every possible short
//!   message (all 2^n blocks, K=3/5/7, n ≤ 12) is encoded circularly
//!   and decoded by both the `wava` engine and the brute-force oracle
//!   (`tests/support`): the outputs must be bit-exact;
//! * **noisy ML parity** — on AWGN blocks, whenever the wrap decode
//!   converges on its first iteration its path is provably the
//!   maximum-likelihood tail-biting path (the best unconstrained path
//!   is circular, and every circular path is an unconstrained path),
//!   so it must match the oracle bit-exactly;
//! * **oracle optimality** — the oracle's codeword never scores below
//!   wava's emission (the oracle really is ML);
//! * **circular-shift equivariance** — rotating the received LLRs by
//!   s stages rotates the decoded bits by s;
//! * **one-iteration WAVA ≡ best-state truncated decode** — iteration
//!   one with all-equal initial metrics is exactly
//!   `ScalarDecoder::decode(llrs, None, BestMetric)`, bit for bit.

mod support;

use support::{message_bits, noiseless_llrs, rotate_left, BruteForceTailBiting};
use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::viterbi::{
    registry, BuildParams, DecodeRequest, Engine as _, ScalarDecoder, StreamEnd,
    TracebackStart, WavaEngine,
};

/// The (K, n) grid of the exhaustive suites: every built constraint
/// length with a block short enough to enumerate all 2^n messages.
const GRID: [(u32, usize); 3] = [(3, 8), (5, 10), (7, 12)];

fn wava_engine(spec: &CodeSpec) -> WavaEngine {
    WavaEngine::with_default_iters(spec.clone())
}

fn noisy_tail_biting_block(
    spec: &CodeSpec,
    n: usize,
    ebn0: f64,
    rng: &mut Rng64,
) -> (Vec<u8>, Vec<f32>) {
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(spec, &bits, Termination::TailBiting);
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), rng);
    (bits, llr::llrs_from_samples(&rx, ch.sigma()))
}

#[test]
fn wava_is_bit_exact_with_brute_force_ml_on_all_enumerated_blocks() {
    // The acceptance criterion: enumerate EVERY message, encode
    // circularly, decode noiselessly — wava must agree with the
    // exhaustive ML reference (and both with the message) on all of
    // them, for K = 3, 5 and 7.
    for &(k, n) in &GRID {
        let spec = CodeSpec::for_constraint(k);
        let oracle = BruteForceTailBiting::new(spec.clone(), n);
        assert!(
            oracle.is_injective(),
            "K={k} n={n}: tail-biting map must be injective for ML to be defined"
        );
        let engine = wava_engine(&spec);
        for m in 0u64..(1u64 << n) {
            let msg = message_bits(m, n);
            let coded = encode(&spec, &msg, Termination::TailBiting);
            let llrs = noiseless_llrs(&coded);
            let out = engine
                .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
                .expect("wava decode");
            let ml = oracle.decode(&llrs);
            assert_eq!(out.bits, ml, "K={k} n={n} m={m}: wava vs brute-force ML");
            assert_eq!(out.bits, msg, "K={k} n={n} m={m}: ML must recover the message");
            assert_eq!(
                out.stats.iterations,
                Some(1),
                "K={k} n={n} m={m}: noiseless blocks close on the first wrap"
            );
        }
    }
}

#[test]
fn wava_matches_brute_force_ml_on_noisy_first_wrap_blocks() {
    // Noisy parity: when the first wrap converges, wava's path is
    // provably the ML tail-biting path, so the oracle must agree bit
    // for bit. Across the suite's SNRs the first wrap closes on the
    // large majority of blocks — assert that too, so this test cannot
    // silently degrade into checking nothing.
    for &(k, n) in &GRID {
        let spec = CodeSpec::for_constraint(k);
        let oracle = BruteForceTailBiting::new(spec.clone(), n);
        assert!(oracle.is_injective());
        let engine = wava_engine(&spec);
        let mut rng = Rng64::seeded(0x7B17_0000 + k as u64);
        let mut first_wrap = 0usize;
        let blocks = 60usize;
        for _ in 0..blocks {
            let (_msg, llrs) = noisy_tail_biting_block(&spec, n, 4.0, &mut rng);
            let out = engine
                .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
                .expect("wava decode");
            if out.stats.iterations == Some(1) {
                first_wrap += 1;
                let ml = oracle.decode(&llrs);
                assert_eq!(out.bits, ml, "K={k}: first-wrap block diverged from ML");
            }
        }
        assert!(
            first_wrap * 2 > blocks,
            "K={k}: only {first_wrap}/{blocks} blocks closed on the first wrap"
        );
    }
}

#[test]
fn oracle_codeword_never_scores_below_wavas() {
    // The oracle is ML by construction: whatever wava emits, encoding
    // it circularly can never beat the oracle's score. (Also pins the
    // score convention both sides share.)
    for &(k, n) in &GRID {
        let spec = CodeSpec::for_constraint(k);
        let oracle = BruteForceTailBiting::new(spec.clone(), n);
        let engine = wava_engine(&spec);
        let mut rng = Rng64::seeded(0x7B17_1000 + k as u64);
        for _ in 0..40 {
            let (_msg, llrs) = noisy_tail_biting_block(&spec, n, 2.0, &mut rng);
            let out = engine
                .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
                .expect("wava decode");
            let (_ml, ml_score) = oracle.decode_scored(&llrs);
            let wava_word = encode(&spec, &out.bits, Termination::TailBiting);
            let wava_score = support::codeword_score(&wava_word, &llrs);
            assert!(
                ml_score >= wava_score - 1e-3,
                "K={k}: oracle score {ml_score} below wava's {wava_score}"
            );
        }
    }
}

#[test]
fn rotating_the_received_llrs_rotates_the_decoded_bits() {
    // Circular-shift equivariance. Noiseless blocks: exact and
    // unconditional (rotating a tail-biting codeword gives the
    // codeword of the rotated message). Noisy blocks: whenever both
    // decodes close on the first wrap, both are ML and ML is
    // shift-equivariant — assert exact equality there.
    for &(k, n) in &[(5u32, 40usize), (7, 48)] {
        let spec = CodeSpec::for_constraint(k);
        let beta = spec.beta as usize;
        let engine = wava_engine(&spec);
        let mut rng = Rng64::seeded(0x7B17_2000 + k as u64);

        // The encoder-level circular property the decoder test rides on.
        let mut msg = vec![0u8; n];
        rng.fill_bits(&mut msg);
        let coded = encode(&spec, &msg, Termination::TailBiting);
        for s in [1usize, 7, n - 3] {
            assert_eq!(
                encode(&spec, &rotate_left(&msg, s), Termination::TailBiting),
                rotate_left(&coded, s * beta),
                "K={k} s={s}: tail-biting encoding must commute with rotation"
            );
        }

        // Noiseless: exact equivariance of the decoder.
        let llrs = noiseless_llrs(&coded);
        let base = engine
            .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
            .unwrap()
            .bits;
        for s in [1usize, 7, n - 3] {
            let rot = rotate_left(&llrs, s * beta);
            let out = engine
                .decode(&DecodeRequest::hard(&rot, n, StreamEnd::TailBiting))
                .unwrap()
                .bits;
            assert_eq!(out, rotate_left(&base, s), "K={k} s={s}: noiseless equivariance");
        }

        // Noisy: conditional on both sides closing their first wrap.
        let mut checked = 0usize;
        for _ in 0..30 {
            let (_msg, llrs) = noisy_tail_biting_block(&spec, n, 4.0, &mut rng);
            let s = 11usize;
            let a = engine
                .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
                .unwrap();
            let rot = rotate_left(&llrs, s * beta);
            let b = engine
                .decode(&DecodeRequest::hard(&rot, n, StreamEnd::TailBiting))
                .unwrap();
            if a.stats.iterations == Some(1) && b.stats.iterations == Some(1) {
                assert_eq!(
                    b.bits,
                    rotate_left(&a.bits, s),
                    "K={k}: noisy first-wrap equivariance"
                );
                checked += 1;
            }
        }
        assert!(checked > 10, "K={k}: only {checked}/30 noisy rotations were checkable");
    }
}

#[test]
fn one_iteration_wava_is_exactly_a_best_state_truncated_decode() {
    // Iteration one starts all states equal and traces from the best
    // final metric — precisely ScalarDecoder::decode(llrs, None,
    // BestMetric). Bit-exact, on both the SIMD lane core (butterfly
    // codes) and the scalar fallback (a non-butterfly code).
    let codes = [
        CodeSpec::standard_k5(),
        CodeSpec::standard_k7(),
        CodeSpec::standard_k7_r3(),
        // MSB-clear generators defeat the butterfly/lane fast path, so
        // this exercises wava's scalar fallback core.
        CodeSpec::new(5, vec![0o13, 0o15]),
    ];
    for spec in codes {
        let one_iter = WavaEngine::new(spec.clone(), 1);
        let mut rng = Rng64::seeded(0x7B17_3000 + spec.generators[0] as u64);
        // 5000 crosses the 4096-stage periodic-renormalization
        // boundary, so the equality also pins wava's renorm schedule
        // against ScalarDecoder's.
        for n in [37usize, 128, 600, 5000] {
            // Arbitrary noisy LLRs (around a codeword at low SNR, so
            // plenty of blocks genuinely disagree with the message).
            let (_msg, llrs) = noisy_tail_biting_block(&spec, n, 0.5, &mut rng);
            let via_wava = one_iter
                .decode(&DecodeRequest::hard(&llrs, n, StreamEnd::TailBiting))
                .expect("wava decode")
                .bits;
            let mut dec = ScalarDecoder::new(spec.clone());
            let truncated = dec.decode(&llrs, None, TracebackStart::BestMetric);
            assert_eq!(
                via_wava,
                truncated,
                "{:?} n={n}: one-iteration wava must equal best-state truncated",
                spec.generators
            );
        }
    }
}

#[test]
fn registry_built_wava_decodes_tail_biting_like_the_direct_engine() {
    // The registry constructor and a hand-built engine must be the
    // same decoder (guards the BuildParams plumbing).
    let spec = CodeSpec::standard_k7();
    let params = BuildParams { spec: spec.clone(), ..BuildParams::paper_default() };
    let from_registry = (registry::find("wava").unwrap().build)(&params);
    let direct = wava_engine(&spec);
    let mut rng = Rng64::seeded(0x7B17_4000);
    let (_msg, llrs) = noisy_tail_biting_block(&spec, 200, 3.0, &mut rng);
    let req = DecodeRequest::hard(&llrs, 200, StreamEnd::TailBiting);
    let a = from_registry.decode(&req).unwrap();
    let b = direct.decode(&req).unwrap();
    assert_eq!(a.bits, b.bits);
    assert_eq!(a.stats.iterations, b.stats.iterations);
}
