//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! check bit-exactness against the native rust engines.
//!
//! Requires `make artifacts` (these tests skip with a notice if the
//! artifact directory is missing, so plain `cargo test` still passes in
//! a fresh checkout).

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::runtime::{Manifest, PjrtEngine, PjrtRuntime, ExecutorPool};
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelTraceback, StartPolicy, StreamEnd, TiledEngine,
    TracebackMode,
};

fn manifest() -> Option<Manifest> {
    match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn native_equivalent(m: &viterbi::runtime::ArtifactMeta) -> TiledEngine {
    TiledEngine::new(
        m.spec.clone(),
        m.geo,
        if m.f0 >= m.geo.f {
            TracebackMode::FrameSerial
        } else {
            TracebackMode::Parallel(ParallelTraceback::new(
                m.f0,
                m.geo.v2,
                StartPolicy::StoredArgmax,
            ))
        },
    )
}

#[test]
fn pjrt_decodes_noiseless_k5() {
    let Some(manifest) = manifest() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let pool = ExecutorPool::load_family(&rt, &manifest, "test_k5_f32_b2").unwrap();
    let engine = PjrtEngine::new(pool);

    let spec = CodeSpec::standard_k5();
    let mut rng = Rng64::seeded(71);
    let mut bits = vec![0u8; 320]; // 10 frames of f=32
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let llrs: Vec<f32> = enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
    let out = engine
        .decode(&DecodeRequest::hard(&llrs, bits.len(), StreamEnd::Truncated))
        .unwrap()
        .bits;
    assert_eq!(out, bits);
}

#[test]
fn pjrt_matches_native_engine_on_noisy_stream() {
    // The PJRT artifact and the native unified engine implement the
    // same algorithm with the same tie-breaking; on identical padded
    // frames they must agree bit-for-bit. The native engine here is
    // driven through the same uniform-frame path (zero padding) by
    // decoding each artifact-shaped frame block.
    let Some(manifest) = manifest() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let pool = ExecutorPool::load_family(&rt, &manifest, "test_k5_f32_b2").unwrap();
    let meta = pool.meta().clone();
    let engine = PjrtEngine::new(pool);

    let spec = CodeSpec::standard_k5();
    let mut rng = Rng64::seeded(72);
    let mut bits = vec![0u8; 32 * 8];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let ch = AwgnChannel::new(2.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());

    let pjrt_out = engine
        .decode(&DecodeRequest::hard(&llrs, bits.len(), StreamEnd::Truncated))
        .unwrap()
        .bits;

    // Native engine fed the exact same zero-padded frame blocks.
    let native = native_equivalent(&meta);
    let beta = spec.beta as usize;
    let mut native_out = vec![0u8; bits.len()];
    let n_frames = bits.len() / meta.geo.f;
    for i in 0..n_frames {
        let mut block = vec![0.0f32; meta.l * beta];
        engine.frame_block(&llrs, bits.len(), i, &mut block);
        let span = viterbi::frames::plan::FrameSpan {
            index: i, // 0 pins state 0 exactly like the pm0 row
            start: 0,
            len: meta.l,
            out_start: meta.geo.v1,
            out_len: meta.geo.f,
        };
        let mut scratch =
            viterbi::viterbi::FrameScratch::new(spec.num_states(), meta.l);
        native.decode_frame(
            &block,
            &span,
            usize::MAX, // never "last" → BestMetric, like the artifact
            StreamEnd::Truncated,
            &mut scratch,
            &mut native_out[i * meta.geo.f..(i + 1) * meta.geo.f],
        );
    }
    assert_eq!(pjrt_out, native_out, "PJRT vs native bit-exactness");
}

#[test]
fn pjrt_ref_artifact_matches_unified_serial() {
    // The pure-jnp baseline graph (method (b)) at the test shape must
    // agree with the unified kernel in serial mode on the same frames…
    // except the unified test artifact uses f0=8 (parallel tb). So
    // compare it against the native serial engine instead.
    let Some(manifest) = manifest() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let pool = ExecutorPool::load_family(&rt, &manifest, "ref_k5_f32_b2").unwrap();
    let meta = pool.meta().clone();
    let engine = PjrtEngine::new(pool);

    let spec = CodeSpec::standard_k5();
    let mut rng = Rng64::seeded(73);
    let mut bits = vec![0u8; 32 * 4];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let ch = AwgnChannel::new(3.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());

    let pjrt_out = engine
        .decode(&DecodeRequest::hard(&llrs, bits.len(), StreamEnd::Truncated))
        .unwrap()
        .bits;

    let native = TiledEngine::new(spec.clone(), meta.geo, TracebackMode::FrameSerial);
    let beta = spec.beta as usize;
    let mut native_out = vec![0u8; bits.len()];
    for i in 0..bits.len() / meta.geo.f {
        let mut block = vec![0.0f32; meta.l * beta];
        engine.frame_block(&llrs, bits.len(), i, &mut block);
        let span = viterbi::frames::plan::FrameSpan {
            index: i,
            start: 0,
            len: meta.l,
            out_start: meta.geo.v1,
            out_len: meta.geo.f,
        };
        let mut scratch =
            viterbi::viterbi::FrameScratch::new(spec.num_states(), meta.l);
        native.decode_frame(
            &block,
            &span,
            usize::MAX,
            StreamEnd::Truncated,
            &mut scratch,
            &mut native_out[i * meta.geo.f..(i + 1) * meta.geo.f],
        );
    }
    assert_eq!(pjrt_out, native_out);
}

#[test]
fn pjrt_bucket_routing_handles_odd_frame_counts() {
    let Some(manifest) = manifest() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let pool = ExecutorPool::load_family(&rt, &manifest, "test_k5_f32_b2").unwrap();
    let engine = PjrtEngine::new(pool);
    let spec = CodeSpec::standard_k5();

    // 3 frames through a batch-2 artifact: one full bucket + one padded.
    let mut rng = Rng64::seeded(74);
    let mut bits = vec![0u8; 32 * 3];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let llrs: Vec<f32> = enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
    let out = engine
        .decode(&DecodeRequest::hard(&llrs, bits.len(), StreamEnd::Truncated))
        .unwrap()
        .bits;
    assert_eq!(out, bits);

    // Partial last frame (stream not a multiple of f).
    let mut bits2 = vec![0u8; 32 * 2 + 17];
    rng.fill_bits(&mut bits2);
    let enc2 = encode(&spec, &bits2, Termination::Truncated);
    let llrs2: Vec<f32> = enc2.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
    let out2 = engine
        .decode(&DecodeRequest::hard(&llrs2, bits2.len(), StreamEnd::Truncated))
        .unwrap()
        .bits;
    assert_eq!(out2.len(), bits2.len());
    // Tail stages beyond the encoder stream lack right context; all but
    // the last few bits must still be exact on a noiseless channel.
    assert_eq!(&out2[..bits2.len() - 8], &bits2[..bits2.len() - 8]);
}

#[test]
fn geometry_smoke_main_artifacts() {
    let Some(manifest) = manifest() else { return };
    for name in ["serial_f256_v20_b8", "ptb_f256_v45_b8"] {
        let a = manifest.find(name).expect(name);
        assert_eq!(a.spec, CodeSpec::standard_k7());
        assert_eq!(a.geo.f, 256);
        assert_eq!(a.geo, FrameGeometry::new(256, a.geo.v1, a.geo.v2));
    }
}

#[test]
fn decode_server_with_pjrt_backend() {
    // Full L3 path over the AOT artifact: submit concurrent requests,
    // verify decoded bits and batching metrics.
    if manifest().is_none() {
        return;
    }
    use std::sync::Arc;
    use viterbi::coordinator::{BackendSpec, BatchPolicy, DecodeServer, ServerConfig};

    let server = Arc::new(
        DecodeServer::start(ServerConfig {
            backend: BackendSpec::Pjrt {
                artifact: "test_k5_f32_b2".into(),
                artifact_dir: None,
            },
            batch: BatchPolicy {
                max_batch: 2,
                max_wait: std::time::Duration::from_millis(1),
            },
            high_watermark: 64,
            low_watermark: 16,
        })
        .unwrap(),
    );

    let spec = CodeSpec::standard_k5();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let server = Arc::clone(&server);
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng64::seeded(200 + t);
            let n = 96 + (t as usize) * 32;
            let mut bits = vec![0u8; n];
            rng.fill_bits(&mut bits);
            let enc = encode(&spec, &bits, Termination::Truncated);
            let llrs: Vec<f32> =
                enc.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect();
            let resp = server.decode_blocking(llrs, StreamEnd::Truncated).unwrap();
            assert_eq!(resp.bits, bits, "request {t}");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.responses, 4);
    assert!(server.backend_name().starts_with("pjrt:"), "{}", server.backend_name());
}
