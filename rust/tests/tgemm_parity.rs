//! Parity gate for the tropical-GEMM engine (`tgemm`): bit-exact
//! against the `unified` reference.
//!
//! The equivalence argument has two halves, both pinned here:
//!
//! 1. `tgemm`'s tiled min-plus sweep computes the same f32 expression
//!    per state as the scalar butterfly, in the same per-element
//!    order — tiling and stage batching only regroup independent
//!    updates — so it matches the whole-stream decode bitwise.
//! 2. `unified` with a degenerate geometry (frame and traceback
//!    subframe at least as long as the stream) *is* the whole-stream
//!    decode. So against that geometry the parity claim is exact,
//!    message by message, not statistical.
//!
//! K = 3/5/7 are swept exhaustively over every short message;
//! K = 9 (the constraint length the planner prefers `tgemm` for) gets
//! randomized noisy streams, both terminated and truncated, plus an
//! overlapped production geometry at high SNR. A blocking sweep pins
//! that the (batch, tile) levers never change a single output bit.

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::viterbi::{registry, BuildParams, DecodeRequest, Engine, StreamEnd, TgemmEngine};

fn run(e: &dyn Engine, llrs: &[f32], stages: usize, end: StreamEnd) -> Vec<u8> {
    e.decode(&DecodeRequest::hard(llrs, stages, end)).expect("decode").bits
}

/// The `unified` reference in its degenerate whole-stream
/// configuration: one frame covering the whole stream, parallel
/// traceback subframe covering the whole frame — exactly the scalar
/// whole-stream recursion, which is what `tgemm` claims bit-parity
/// with.
fn unified_whole_stream(spec: &CodeSpec, stages: usize) -> std::sync::Arc<dyn Engine> {
    let f = stages.max(16);
    let p = BuildParams {
        spec: spec.clone(),
        geo: FrameGeometry::new(f, 4, 4),
        f0: f,
        threads: 1,
        delay: 96,
        lanes: 8,
        stream_stages: stages,
    };
    (registry::find("unified").expect("unified registered").build)(&p)
}

/// Noiseless LLRs for an encoded stream: +4.0 for a transmitted 0,
/// −4.0 for a transmitted 1 (the repo's noiseless-parity idiom).
fn noiseless_llrs(coded: &[u8]) -> Vec<f32> {
    coded.iter().map(|&b| if b == 0 { 4.0 } else { -4.0 }).collect()
}

/// Noisy terminated-or-truncated workload at `ebn0` dB.
fn workload(
    spec: &CodeSpec,
    n: usize,
    ebn0: f64,
    seed: u64,
    term: Termination,
) -> (Vec<u8>, Vec<f32>, usize) {
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(spec, &bits, term);
    let stages = match term {
        Termination::Terminated => n + (spec.k as usize - 1),
        _ => n,
    };
    let ch = AwgnChannel::new(ebn0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&enc), &mut rng);
    (bits, llr::llrs_from_samples(&rx, ch.sigma()), stages)
}

#[test]
fn exhaustive_short_messages_match_unified_bit_for_bit() {
    // Every message of every length up to the cap, both stream ends,
    // K = 3/5/7. Noiseless, so besides the engine-vs-engine parity the
    // decode must also invert the encoder exactly (the standard codes
    // are non-catastrophic and the start state is known, so the ML
    // path is unique at zero noise).
    for (k, max_n) in [(3u32, 8usize), (5, 8), (7, 6)] {
        let spec = CodeSpec::for_constraint(k);
        for n in 1..=max_n {
            for msg in 0u32..(1u32 << n) {
                let bits: Vec<u8> = (0..n).map(|i| ((msg >> i) & 1) as u8).collect();
                for (term, end) in [
                    (Termination::Terminated, StreamEnd::Terminated),
                    (Termination::Truncated, StreamEnd::Truncated),
                ] {
                    let llrs = noiseless_llrs(&encode(&spec, &bits, term));
                    let stages = match term {
                        Termination::Terminated => n + (k as usize - 1),
                        _ => n,
                    };
                    let tgemm = TgemmEngine::new(spec.clone());
                    let got = run(&tgemm, &llrs, stages, end);
                    let want =
                        run(unified_whole_stream(&spec, stages).as_ref(), &llrs, stages, end);
                    assert_eq!(got, want, "K={k} n={n} msg={msg:#b} {term:?}: tgemm vs unified");
                    assert_eq!(
                        &got[..n],
                        &bits[..],
                        "K={k} n={n} msg={msg:#b} {term:?}: not the transmitted message"
                    );
                }
            }
        }
    }
}

#[test]
fn k9_noisy_streams_match_unified_bit_for_bit() {
    // The constraint length the planner routes to tgemm: randomized
    // noisy streams near the waterfall, where the decoded bits depend
    // on every metric comparison — structural parity, not just
    // both-error-free agreement. Both stream ends (the truncated end
    // takes the argmax start, a different final-traceback path).
    let spec = CodeSpec::standard_k9();
    for (term, end) in [
        (Termination::Terminated, StreamEnd::Terminated),
        (Termination::Truncated, StreamEnd::Truncated),
    ] {
        for seed in [0x7634_0900u64, 0x7634_0901, 0x7634_0902] {
            let (_bits, llrs, stages) = workload(&spec, 4000, 3.0, seed, term);
            let tgemm = TgemmEngine::new(spec.clone());
            let got = run(&tgemm, &llrs, stages, end);
            let want = run(unified_whole_stream(&spec, stages).as_ref(), &llrs, stages, end);
            assert_eq!(got, want, "K=9 seed={seed:#x} {term:?}");
        }
    }
}

#[test]
fn k9_overlapped_production_geometry_agrees_at_high_snr() {
    // The registry-default comparison the bench gate runs: unified in
    // an overlapped production geometry (256-stage frames, 48/72
    // overlap, 32-stage parallel traceback). Far above the waterfall
    // both decoders recover the transmitted stream exactly, so they
    // agree with each other through it.
    let spec = CodeSpec::standard_k9();
    let (bits, llrs, stages) = workload(&spec, 8192, 10.0, 0x7634_0910, Termination::Terminated);
    let p = BuildParams {
        spec: spec.clone(),
        geo: FrameGeometry::new(256, 48, 72),
        f0: 32,
        threads: 1,
        delay: 96,
        lanes: 8,
        stream_stages: stages,
    };
    let unified = (registry::find("unified").unwrap().build)(&p);
    let tgemm = TgemmEngine::new(spec.clone());
    let got = run(&tgemm, &llrs, stages, StreamEnd::Terminated);
    let want = run(unified.as_ref(), &llrs, stages, StreamEnd::Terminated);
    assert_eq!(&got[..bits.len()], &bits[..], "tgemm not error-free at 10 dB");
    assert_eq!(got, want, "tgemm vs overlapped unified at 10 dB");
}

#[test]
fn blocking_sweep_never_changes_the_output() {
    // Stage batching and state tiling are pure execution-layout
    // levers: every (batch, tile) pair — degenerate, tiny, L1-sized,
    // and larger than the state space — decodes the identical bit
    // stream on a noisy input where any arithmetic reordering would
    // show.
    for (spec, seed) in
        [(CodeSpec::standard_k7(), 0x7634_0920u64), (CodeSpec::standard_k9(), 0x7634_0921)]
    {
        let (_bits, llrs, stages) = workload(&spec, 3000, 3.0, seed, Termination::Terminated);
        let reference = run(&TgemmEngine::new(spec.clone()), &llrs, stages, StreamEnd::Terminated);
        for (batch, tile) in [(1usize, 1usize), (1, 64), (4, 8), (16, 1000), (64, 512), (256, 7)] {
            let e = TgemmEngine::with_blocking(spec.clone(), batch, tile);
            let out = run(&e, &llrs, stages, StreamEnd::Terminated);
            assert_eq!(
                out,
                reference,
                "K={} blocking (B={batch}, T={tile}) changed the output",
                spec.k
            );
        }
    }
}
