//! End-to-end adaptive dispatch through the decode service: a
//! `DecodeServer` on `BackendSpec::Auto` (baseline calibration profile
//! loaded) must route a uniform 64-frame batch through the SIMD lane
//! route and a ragged single-frame batch through a per-frame route —
//! verified by the `MetricsSnapshot` dispatch counters — while staying
//! bit-exact with the requests' payloads.

use std::path::Path;
use std::time::Duration;

use viterbi::channel::Rng64;
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::coordinator::{BackendSpec, BatchPolicy, DecodeServer, ServerConfig};
use viterbi::frames::plan::FrameGeometry;
use viterbi::viterbi::StreamEnd;

fn baseline_profile() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../calibration/baseline.jsonl")
}

fn auto_server(max_batch: usize, wait_ms: u64) -> DecodeServer {
    DecodeServer::start(ServerConfig {
        backend: BackendSpec::Auto {
            spec: CodeSpec::standard_k5(),
            geo: FrameGeometry::new(32, 8, 12),
            f0: 8,
            threads: 4,
            budget_bytes: None,
            profile: Some(baseline_profile()),
        },
        batch: BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) },
        high_watermark: 4096,
        low_watermark: 1024,
    })
    .unwrap()
}

fn noiseless_request(seed: u64, n: usize) -> (Vec<u8>, Vec<f32>) {
    let spec = CodeSpec::standard_k5();
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let enc = encode(&spec, &bits, Termination::Truncated);
    let llrs = enc.iter().map(|&b| if b == 0 { 3.0 } else { -3.0 }).collect();
    (bits, llrs)
}

#[test]
fn baseline_profile_is_checked_in_and_loadable() {
    let path = baseline_profile();
    assert!(path.is_file(), "missing {}", path.display());
    let profile = viterbi::tuner::CalibrationProfile::read_jsonl(&path).unwrap();
    assert!(!profile.is_empty());
    // The baseline covers every dispatch candidate.
    for engine in viterbi::tuner::DISPATCH_CANDIDATES {
        assert!(
            profile.records.iter().any(|r| r.engine == engine),
            "baseline has no {engine} cells"
        );
    }
}

#[test]
fn uniform_batch_takes_the_lane_route_and_ragged_a_frame_route() {
    let server = auto_server(64, 30);
    // One request that chunks into exactly 64 uniform frames: the
    // batcher flushes a full 64-job batch, which the planner must send
    // down the SIMD lane route.
    let (bits, llrs) = noiseless_request(0xA07A, 64 * 32);
    let resp = server.decode_blocking(llrs, StreamEnd::Truncated).unwrap();
    assert_eq!(resp.bits, bits);
    assert_eq!(resp.frames, 64);
    let m = server.metrics();
    let lane_frames = m.dispatched("lanes") + m.dispatched("lanes-mt");
    assert_eq!(
        lane_frames, 64,
        "uniform 64-frame batch must take a lane route: {:?}",
        m.dispatch
    );
    // A single-frame request arrives alone (deadline flush): ragged
    // work goes down a per-frame route, never the lane route.
    let (bits1, llrs1) = noiseless_request(0xA07B, 20);
    let resp1 = server.decode_blocking(llrs1, StreamEnd::Truncated).unwrap();
    assert_eq!(resp1.bits, bits1);
    assert_eq!(resp1.frames, 1);
    let m = server.metrics();
    assert_eq!(
        m.dispatched("lanes") + m.dispatched("lanes-mt"),
        64,
        "lane counters must not grow: {:?}",
        m.dispatch
    );
    assert_eq!(
        m.dispatched("unified") + m.dispatched("parallel"),
        1,
        "single frame must take a per-frame route: {:?}",
        m.dispatch
    );
    assert!(server.backend_name().starts_with("auto:"), "{}", server.backend_name());
}

#[test]
fn auto_server_survives_concurrent_mixed_traffic() {
    let server = std::sync::Arc::new(auto_server(8, 1));
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let server = std::sync::Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let (bits, llrs) = noiseless_request(0xC0 + t, 32 * (1 + t as usize * 3));
            let resp = server.decode_blocking(llrs, StreamEnd::Truncated).unwrap();
            assert_eq!(resp.bits, bits, "stream {t}");
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.responses, 6);
    // Every decoded frame was attributed to some route.
    let routed: u64 = m.dispatch.iter().map(|(_, n)| *n).sum();
    assert_eq!(routed, m.frames, "dispatch counters must cover all frames");
}
