//! Registry smoke test: every engine in `viterbi::registry` must
//! round-trip a K=7, rate-1/2 frame at high SNR with zero bit errors.
//! This guards the registry against silently dropping an engine (the
//! bench harness, the docs and the CLI all enumerate engines from it).

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::bits::count_bit_errors;
use viterbi::viterbi::{registry, BuildParams, DecodeRequest, Engine as _, StreamEnd};

fn high_snr_workload(n: usize, seed: u64) -> (Vec<u8>, Vec<f32>, usize) {
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(seed);
    let mut bits = vec![0u8; n];
    rng.fill_bits(&mut bits);
    let coded = encode(&spec, &bits, Termination::Terminated);
    // 10 dB Eb/N0: far above the waterfall; any correct decoder is
    // error-free here, so a single bit error means a real defect.
    let ch = AwgnChannel::new(10.0, spec.rate());
    let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());
    (bits, llrs, n + 6)
}

#[test]
fn every_registry_engine_roundtrips_k7_frame_error_free() {
    let params = BuildParams {
        spec: CodeSpec::standard_k7(),
        geo: FrameGeometry::new(256, 20, 45),
        f0: 32,
        threads: 4,
        delay: 96,
        // Narrow lanes so the 17-frame stream exercises several lane
        // groups including a ragged tail group.
        lanes: 8,
        stream_stages: 4096 + 6,
    };
    let (bits, llrs, stages) = high_snr_workload(4096, 0x5140);
    let reg = registry();
    assert_eq!(reg.len(), 12, "engine silently dropped from the registry");
    for entry in &reg {
        let engine = (entry.build)(&params);
        let out = engine
            .decode(&DecodeRequest::hard(&llrs, stages, StreamEnd::Terminated))
            .expect("decode")
            .bits;
        assert_eq!(out.len(), stages, "{}: wrong output length", entry.name);
        let errors = count_bit_errors(&out[..bits.len()], &bits);
        assert_eq!(
            errors, 0,
            "{} ({}) must decode a high-SNR K=7 rate-1/2 frame error-free",
            entry.name,
            engine.name()
        );
    }
}

#[test]
fn registry_names_match_bench_cli_contract() {
    // The names the `bench --engines` flag accepts are exactly these;
    // BENCHMARKS.md documents them. Renaming one is a breaking change
    // to recorded BENCH_*.json baselines.
    let names: Vec<&str> = registry().iter().map(|e| e.name).collect();
    assert_eq!(
        names,
        [
            "scalar", "tiled", "unified", "parallel", "lanes", "lanes-mt", "blocks", "tgemm",
            "streaming", "hard", "wava", "auto"
        ]
    );
}

#[test]
fn capability_flags_match_the_documented_matrix() {
    // The README engine table's capability columns, as code: exactly
    // these engines implement SOVA soft output, and exactly these
    // decode tail-biting streams. Flipping a flag without porting the
    // capability (or vice versa) breaks this test and engine_api.rs.
    let soft: Vec<&str> = registry().iter().filter(|e| e.soft_output).map(|e| e.name).collect();
    assert_eq!(soft, ["scalar", "tiled", "unified", "auto"]);
    let tail_biting: Vec<&str> =
        registry().iter().filter(|e| e.tail_biting).map(|e| e.name).collect();
    assert_eq!(tail_biting, ["wava", "auto"]);
    // The tropical-matrix engine's row: hard-output linear streams
    // only, like the other whole-stream accelerators.
    let tgemm = registry::find("tgemm").expect("tgemm registered");
    assert!(!tgemm.soft_output, "tgemm has no SOVA port");
    assert!(!tgemm.tail_biting, "tgemm decodes linear streams only");
    // No engine advertises a nonzero soft-margin working set without
    // advertising soft output itself.
    let params = BuildParams {
        spec: CodeSpec::standard_k7(),
        geo: FrameGeometry::new(256, 20, 45),
        f0: 32,
        threads: 2,
        delay: 96,
        lanes: 8,
        stream_stages: 4096,
    };
    for e in registry() {
        assert_eq!(
            (e.soft_margin_bytes)(&params) > 0,
            e.soft_output,
            "{}: soft margin rule disagrees with the soft flag",
            e.name
        );
    }
}
