//! Semiring property suite for the tropical-GEMM ACS engine: the
//! min-plus matrix algebra the `tgemm` engine is built on must actually
//! be a semiring on the representable inputs, and every blocking the
//! engine applies (cache tiles, stage batching) must be output-
//! invariant. Matrices here use *integer-valued* f32 entries so that
//! float addition is exactly associative and the algebraic identities
//! hold bitwise, not just approximately — the same reason min over
//! non-NaN floats is order-independent makes the blocked kernels
//! exactly equal to the naive ones even on continuous inputs.

use viterbi::channel::Rng64;
use viterbi::code::{CodeSpec, Trellis};
use viterbi::util::check;
use viterbi::viterbi::{
    stage_matrix, tropical_identity, tropical_matmul_blocked, tropical_matmul_naive,
    tropical_matvec, TROPICAL_ZERO,
};

/// Random n×n tropical matrix: integer values in [-32, 32], with a
/// quarter of the entries set to the additive identity `+∞` so the
/// sparse/no-transition paths are exercised.
fn gen_matrix(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n * n)
        .map(|_| {
            if rng.gen_range_usize(0, 4) == 0 {
                TROPICAL_ZERO
            } else {
                rng.gen_range_usize(0, 65) as f32 - 32.0
            }
        })
        .collect()
}

/// Random length-n tropical vector, integer-valued like the matrices.
fn gen_vector(rng: &mut Rng64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gen_range_usize(0, 65) as f32 - 32.0).collect()
}

/// Bitwise equality (f32::to_bits), so `+∞ == +∞` passes and a stray
/// `-0.0`/NaN would fail loudly instead of comparing equal.
fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: entry {i} diverged ({x} vs {y})");
    }
}

#[test]
fn matmul_is_associative_on_integer_matrices() {
    // (A ⊗ B) ⊗ C = A ⊗ (B ⊗ C): min is associative outright, and with
    // integer entries every three-term sum is exact in f32, so the two
    // parenthesizations agree bitwise.
    check::forall(
        "tropical matmul associativity",
        40,
        0x7634_0001,
        |rng| {
            let n = rng.gen_range_usize(1, 13);
            (gen_matrix(rng, n), gen_matrix(rng, n), gen_matrix(rng, n), n)
        },
        |(a, b, c, n)| {
            let left = tropical_matmul_naive(&tropical_matmul_naive(a, b, *n), c, *n);
            let right = tropical_matmul_naive(a, &tropical_matmul_naive(b, c, *n), *n);
            assert_bitwise_eq(&left, &right, "associativity");
        },
    );
}

#[test]
fn identity_matrix_is_neutral_on_both_sides() {
    check::forall(
        "tropical identity",
        40,
        0x7634_0002,
        |rng| {
            let n = rng.gen_range_usize(1, 17);
            (gen_matrix(rng, n), n)
        },
        |(a, n)| {
            let i = tropical_identity(*n);
            assert_bitwise_eq(&tropical_matmul_naive(&i, a, *n), a, "I ⊗ A");
            assert_bitwise_eq(&tropical_matmul_naive(a, &i, *n), a, "A ⊗ I");
        },
    );
}

#[test]
fn blocked_matmul_matches_naive_for_every_block_size() {
    // The invariance the engine's state tiling rides on: min over
    // non-NaN floats is order-independent, and each candidate sum
    // A[i][k] + B[k][j] is the same f32 value in either loop nest, so
    // reordering by tiles cannot change a single bit. Continuous
    // entries would pass too; integer ones keep the generator shared.
    for n in [1usize, 4, 16, 64] {
        let mut rng = Rng64::seeded(0x7634_0003 ^ n as u64);
        let a = gen_matrix(&mut rng, n);
        let b = gen_matrix(&mut rng, n);
        let reference = tropical_matmul_naive(&a, &b, n);
        for block in [1usize, 2, 3, 5, 8, 16, n, n + 3] {
            let blocked = tropical_matmul_blocked(&a, &b, n, block);
            assert_bitwise_eq(&blocked, &reference, &format!("n={n} block={block}"));
        }
    }
}

#[test]
fn matvec_agrees_with_matmul_against_a_one_column_matrix() {
    // T ⊗ m as a matvec equals the column of the n×n product where m
    // is embedded as a column — the matvec is not a separate algebra.
    check::forall(
        "matvec embeds in matmul",
        40,
        0x7634_0004,
        |rng| {
            let n = rng.gen_range_usize(1, 17);
            (gen_matrix(rng, n), gen_vector(rng, n), n)
        },
        |(t, m, n)| {
            let n = *n;
            // Embed m as column 0 of an otherwise-+∞ matrix.
            let mut mm = vec![TROPICAL_ZERO; n * n];
            for i in 0..n {
                mm[i * n] = m[i];
            }
            let product = tropical_matmul_naive(t, &mm, n);
            let column: Vec<f32> = (0..n).map(|i| product[i * n]).collect();
            assert_bitwise_eq(&tropical_matvec(t, m, n), &column, "matvec vs matmul column");
        },
    );
}

#[test]
fn stage_batching_composes_stage_matrices_exactly() {
    // The algebra behind the engine's stage batching: sweeping two
    // stages one matvec at a time equals pre-composing the stage
    // matrices with one matmul and applying the product once —
    // T₂ ⊗ (T₁ ⊗ m) = (T₂ ⊗ T₁) ⊗ m. With integer-valued LLRs the
    // branch metrics are integers, every sum is exact, and the
    // equality is bitwise.
    for spec in [CodeSpec::standard_k5(), CodeSpec::standard_k7()] {
        let trellis = Trellis::new(spec.clone());
        let ns = trellis.num_states();
        let beta = spec.beta as usize;
        let mut rng = Rng64::seeded(0x7634_0005 ^ spec.k as u64);
        for _ in 0..8 {
            let llrs: Vec<f32> =
                (0..2 * beta).map(|_| rng.gen_range_usize(0, 17) as f32 - 8.0).collect();
            let t1 = stage_matrix(&trellis, &llrs[..beta]);
            let t2 = stage_matrix(&trellis, &llrs[beta..]);
            let m = gen_vector(&mut rng, ns);
            let per_stage = tropical_matvec(&t2, &tropical_matvec(&t1, &m, ns), ns);
            let composed = tropical_matvec(&tropical_matmul_naive(&t2, &t1, ns), &m, ns);
            assert_bitwise_eq(&per_stage, &composed, &format!("K={} composition", spec.k));
        }
    }
}

#[test]
fn stage_matrices_have_exactly_two_finite_entries_per_row_and_column() {
    // The sparsity the engine exploits: for a rate-1/n code every state
    // has exactly two predecessors (row sparsity) and exactly two
    // successors (column sparsity) — T is a permutation-like butterfly,
    // never denser.
    for k in [3u32, 5, 7, 9] {
        let spec = CodeSpec::for_constraint(k);
        let trellis = Trellis::new(spec.clone());
        let ns = trellis.num_states();
        let beta = spec.beta as usize;
        let mut rng = Rng64::seeded(0x7634_0006 ^ k as u64);
        let llrs: Vec<f32> = (0..beta).map(|_| (rng.uniform() as f32 - 0.5) * 8.0).collect();
        let t = stage_matrix(&trellis, &llrs);
        let mut col_counts = vec![0usize; ns];
        for j in 0..ns {
            let row = &t[j * ns..(j + 1) * ns];
            let finite = row.iter().filter(|x| x.is_finite()).count();
            assert_eq!(finite, 2, "K={k}: row {j} has {finite} finite entries");
            for (i, x) in row.iter().enumerate() {
                if x.is_finite() {
                    col_counts[i] += 1;
                }
            }
        }
        assert!(
            col_counts.iter().all(|&c| c == 2),
            "K={k}: column sparsity broken: {col_counts:?}"
        );
    }
}
