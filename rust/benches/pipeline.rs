//! Bench: end-to-end serving pipeline — the L3 coordinator over both
//! backends (native engine and the PJRT AOT artifact), measuring
//! request throughput and the batching machinery's overhead.
//!
//! ```bash
//! make artifacts && cargo bench --bench pipeline [-- --quick]
//! ```

mod harness;

use std::sync::Arc;

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::coordinator::{BackendSpec, BatchPolicy, DecodeServer, ServerConfig};
use viterbi::frames::plan::FrameGeometry;
use viterbi::viterbi::StreamEnd;

fn workload(spec: &CodeSpec, streams: usize, bits: usize) -> Vec<Vec<f32>> {
    let ch = AwgnChannel::new(4.0, spec.rate());
    let mut rng = Rng64::seeded(8);
    (0..streams)
        .map(|_| {
            let mut msg = vec![0u8; bits];
            rng.fill_bits(&mut msg);
            let coded = encode(spec, &msg, Termination::Truncated);
            let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
            llr::llrs_from_samples(&rx, ch.sigma())
        })
        .collect()
}

fn bench_backend(name: &str, backend: BackendSpec, streams: usize, bits: usize, samples: usize) {
    let server = match DecodeServer::start(ServerConfig {
        backend,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(500),
        },
        high_watermark: 8192,
        low_watermark: 2048,
    }) {
        Ok(s) => Arc::new(s),
        Err(e) => {
            println!("{name}: SKIP ({e:#})");
            return;
        }
    };
    let spec = server.chunker().spec.clone();
    let payloads = Arc::new(workload(&spec, streams, bits));

    let r = harness::bench(name, samples, 1, || {
        let ids: Vec<_> = payloads
            .iter()
            .map(|llrs| server.submit(llrs.clone(), StreamEnd::Truncated))
            .collect();
        for id in ids {
            let resp = server.wait(id).expect("decode");
            std::hint::black_box(&resp.bits);
        }
    });
    r.report(Some(((streams * bits) as f64, "Gb/s")));
    println!("{:40} {}", "", server.metrics().render());
}

fn main() {
    let args = harness::parse_args();
    let (streams, bits, samples) =
        if args.quick { (16, 4096, 3) } else { (64, 8192, 5) };

    println!("== pipeline bench: {streams} streams × {bits} bits ==\n");
    if harness::matches_filter(&args, "native") {
        bench_backend(
            "pipeline/native parallel-tb backend",
            BackendSpec::Native {
                spec: CodeSpec::standard_k7(),
                geo: FrameGeometry::new(256, 20, 45),
                f0: Some(32),
            },
            streams,
            bits,
            samples,
        );
    }
    if harness::matches_filter(&args, "pjrt") {
        bench_backend(
            "pipeline/pjrt AOT-artifact backend",
            BackendSpec::Pjrt { artifact: "ptb_f256_v45_b8".into(), artifact_dir: None },
            streams,
            bits,
            samples,
        );
    }
}
