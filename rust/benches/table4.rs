//! Bench: Table IV — regular (serial-traceback) decoder throughput over
//! the paper's f × v2 grid, on the multithreaded native engine, with
//! the V100 occupancy-model prediction alongside.
//!
//! ```bash
//! cargo bench --bench table4              # full grid
//! cargo bench --bench table4 -- --quick   # 2×2 corner
//! ```

mod harness;

use std::sync::Arc;

use viterbi::channel::Rng64;
use viterbi::code::CodeSpec;
use viterbi::frames::plan::FrameGeometry;
use viterbi::memmodel::{GpuParams, OccupancyModel};
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelEngine, StreamEnd, TiledEngine, TracebackMode,
};

fn main() {
    let args = harness::parse_args();
    let (fs, v2s): (Vec<usize>, Vec<usize>) = if args.quick {
        (vec![64, 256], vec![10, 40])
    } else {
        (vec![32, 64, 128, 256, 512], vec![10, 20, 30, 40])
    };
    let stream_bits = if args.quick { 1 << 18 } else { 1 << 21 };
    let samples = if args.quick { 3 } else { 5 };

    let pool = Arc::new(ThreadPool::with_default_parallelism());
    let model = OccupancyModel::new(GpuParams::v100(), 7, 2);
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(4);
    let llrs: Vec<f32> = (0..stream_bits * 2)
        .map(|_| (rng.uniform() as f32 - 0.5) * 8.0)
        .collect();

    println!("== Table IV bench: serial-traceback decoder throughput ==");
    println!("stream: {stream_bits} bits; pool: {} threads\n", pool.size());
    for &v2 in &v2s {
        for &f in &fs {
            let name = format!("table4/f={f}/v2={v2}");
            if !harness::matches_filter(&args, &name) {
                continue;
            }
            let geo = FrameGeometry::new(f, 20, v2);
            let engine = ParallelEngine::new(
                TiledEngine::new(spec.clone(), geo, TracebackMode::FrameSerial),
                Arc::clone(&pool),
            );
            let r = harness::bench(&name, samples, 1, || {
                let out = engine
                    .decode(&DecodeRequest::hard(&llrs, stream_bits, StreamEnd::Truncated))
                    .expect("decode");
                std::hint::black_box(&out);
            });
            r.report(Some((stream_bits as f64, "Gb/s")));
            println!(
                "{:40} V100 occupancy model: {:.2} Gb/s ({} blocks/SM)",
                "",
                model.serial_traceback(geo).gbps,
                model.serial_traceback(geo).blocks_per_sm
            );
        }
    }
}
