//! Shared micro-benchmark harness (criterion is not fetchable in this
//! offline image; `cargo bench` drives these with `harness = false`).
//!
//! Methodology: warm-up runs, then N timed samples of the closure;
//! reports mean ± stddev, min, and a derived throughput when the
//! caller supplies a per-iteration work amount.
//!
//! Included via `mod harness;` by each bench target; not every target
//! uses every helper.
#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

/// Time `iters_per_sample` invocations of `f`, `samples` times.
pub fn bench<F: FnMut()>(name: &str, samples: usize, iters_per_sample: usize, mut f: F) -> BenchResult {
    // Warm-up: one sample's worth.
    for _ in 0..iters_per_sample {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / (times.len().max(2) - 1) as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        samples: times,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: min,
    }
}

impl BenchResult {
    /// Print with optional throughput (work units per iteration).
    pub fn report(&self, work_per_iter: Option<(f64, &str)>) {
        let mut line = format!(
            "{:40} {:>12} ± {:>10}  (min {:>12})",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.stddev_s),
            fmt_time(self.min_s),
        );
        if let Some((work, unit)) = work_per_iter {
            line += &format!("   {:>10.3} {unit}", work / self.mean_s / 1e9);
        }
        println!("{line}");
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Parse `--quick` / filter args that cargo bench passes through.
pub struct BenchArgs {
    pub quick: bool,
    pub filter: Option<String>,
}

pub fn parse_args() -> BenchArgs {
    let mut quick = false;
    let mut filter = None;
    for a in std::env::args().skip(1) {
        match a.as_str() {
            "--quick" => quick = true,
            "--bench" => {}
            s if !s.starts_with('-') => filter = Some(s.to_string()),
            _ => {}
        }
    }
    BenchArgs { quick, filter }
}

pub fn matches_filter(args: &BenchArgs, name: &str) -> bool {
    args.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
}
