//! Bench: Table V — unified parallel-traceback decoder throughput over
//! the paper's f0 × v2 grid (f = 256), native engine + V100 model.
//!
//! ```bash
//! cargo bench --bench table5 [-- --quick]
//! ```

mod harness;

use std::sync::Arc;

use viterbi::channel::Rng64;
use viterbi::code::CodeSpec;
use viterbi::frames::plan::FrameGeometry;
use viterbi::memmodel::{GpuParams, OccupancyModel};
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelEngine, ParallelTraceback, StartPolicy, StreamEnd,
    TiledEngine, TracebackMode,
};

fn main() {
    let args = harness::parse_args();
    let (f0s, v2s): (Vec<usize>, Vec<usize>) = if args.quick {
        (vec![8, 32], vec![25, 45])
    } else {
        (vec![8, 16, 24, 32, 40, 48, 56], vec![25, 30, 35, 40, 45])
    };
    let stream_bits = if args.quick { 1 << 18 } else { 1 << 21 };
    let samples = if args.quick { 3 } else { 5 };
    let (f, v1) = (256usize, 20usize);

    let pool = Arc::new(ThreadPool::with_default_parallelism());
    let model = OccupancyModel::new(GpuParams::v100(), 7, 2);
    let spec = CodeSpec::standard_k7();
    let mut rng = Rng64::seeded(5);
    let llrs: Vec<f32> = (0..stream_bits * 2)
        .map(|_| (rng.uniform() as f32 - 0.5) * 8.0)
        .collect();

    println!("== Table V bench: parallel-traceback decoder throughput ==");
    println!("f = {f}; stream: {stream_bits} bits; pool: {} threads\n", pool.size());
    for &v2 in &v2s {
        for &f0 in &f0s {
            let name = format!("table5/f0={f0}/v2={v2}");
            if !harness::matches_filter(&args, &name) {
                continue;
            }
            let geo = FrameGeometry::new(f, v1, v2);
            let mode = TracebackMode::Parallel(ParallelTraceback::new(
                f0,
                v2,
                StartPolicy::StoredArgmax,
            ));
            let engine =
                ParallelEngine::new(TiledEngine::new(spec.clone(), geo, mode), Arc::clone(&pool));
            let r = harness::bench(&name, samples, 1, || {
                let out = engine
                    .decode(&DecodeRequest::hard(&llrs, stream_bits, StreamEnd::Truncated))
                    .expect("decode");
                std::hint::black_box(&out);
            });
            r.report(Some((stream_bits as f64, "Gb/s")));
            println!(
                "{:40} V100 occupancy model: {:.2} Gb/s",
                "",
                model.parallel_traceback(geo, f0).gbps
            );
        }
    }
}
