//! Micro-benchmarks of the decode hot paths: the ACS stage loop, the
//! whole-frame forward pass, the two traceback variants, the encoder,
//! and the channel front end. These are the units the §Perf pass
//! iterates on.
//!
//! ```bash
//! cargo bench --bench kernels [-- --quick] [-- acs]
//! ```

mod harness;

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination, Trellis};
use viterbi::frames::plan::{FrameGeometry, FrameSpan};
use viterbi::viterbi::{
    tiled::decode_frame_serial, unified::decode_frame_parallel_tb, FrameScratch,
    ParallelTraceback, ScalarDecoder, StartPolicy, TracebackStart,
};

fn main() {
    let args = harness::parse_args();
    let samples = if args.quick { 5 } else { 20 };

    let spec = CodeSpec::standard_k7();
    let trellis = Trellis::new(spec.clone());
    let mut rng = Rng64::seeded(6);

    // A realistic noisy frame at the paper's operating point.
    let geo = FrameGeometry::new(256, 20, 45);
    let span_len = geo.span();
    let mut msg = vec![0u8; span_len];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Truncated);
    let ch = AwgnChannel::new(3.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
    let frame_llrs = llr::llrs_from_samples(&rx, ch.sigma());
    let span = FrameSpan { index: 1, start: 0, len: span_len, out_start: 20, out_len: 256 };

    if harness::matches_filter(&args, "forward+serial_tb") {
        let mut scratch = FrameScratch::new(64, span_len);
        let mut out = vec![0u8; 256];
        let r = harness::bench("frame/forward+serial_tb (321 stages)", samples, 20, || {
            decode_frame_serial(
                &trellis,
                &frame_llrs,
                &span,
                None,
                TracebackStart::BestMetric,
                &mut scratch,
                &mut out,
            );
            std::hint::black_box(&out);
        });
        r.report(Some((256.0, "Gb/s")));
    }

    if harness::matches_filter(&args, "forward+parallel_tb") {
        let mut scratch = FrameScratch::new(64, span_len);
        let mut out = vec![0u8; 256];
        let ptb = ParallelTraceback::new(32, 45, StartPolicy::StoredArgmax);
        let r = harness::bench("frame/forward+parallel_tb (f0=32)", samples, 20, || {
            decode_frame_parallel_tb(
                &trellis,
                &frame_llrs,
                &span,
                None,
                TracebackStart::BestMetric,
                &ptb,
                &mut scratch,
                &mut out,
            );
            std::hint::black_box(&out);
        });
        r.report(Some((256.0, "Gb/s")));
    }

    if harness::matches_filter(&args, "scalar_stream") {
        let n = 1 << 15;
        let mut bits = vec![0u8; n];
        rng.fill_bits(&mut bits);
        let coded = encode(&spec, &bits, Termination::Terminated);
        let stream: Vec<f32> =
            coded.iter().map(|&b| if b == 0 { 2.0 } else { -2.0 }).collect();
        let mut dec = ScalarDecoder::new(spec.clone());
        let r = harness::bench("stream/scalar whole-stream (32k bits)", samples, 1, || {
            let out = dec.decode(&stream, Some(0), TracebackStart::State(0));
            std::hint::black_box(&out);
        });
        r.report(Some((n as f64, "Gb/s")));
    }

    if harness::matches_filter(&args, "encoder") {
        let mut bits = vec![0u8; 1 << 16];
        rng.fill_bits(&mut bits);
        let r = harness::bench("substrate/encoder (64k bits)", samples, 5, || {
            let out = encode(&spec, &bits, Termination::Terminated);
            std::hint::black_box(&out);
        });
        r.report(Some(((1 << 16) as f64, "Gb/s")));
    }

    if harness::matches_filter(&args, "channel") {
        let tx = bpsk::modulate(&vec![0u8; 1 << 16]);
        let ch = AwgnChannel::new(3.0, 0.5);
        let mut rng2 = Rng64::seeded(7);
        let mut out = Vec::new();
        let r = harness::bench("substrate/awgn+llr (64k samples)", samples, 5, || {
            ch.transmit_into(&tx, &mut out, &mut rng2);
            let l = llr::llrs_from_samples(&out, ch.sigma());
            std::hint::black_box(&l);
        });
        r.report(Some(((1 << 16) as f64, "Gsamples/s")));
    }
}
