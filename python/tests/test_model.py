"""L2 model + AOT lowering tests: shapes, kernel-vs-ref-graph parity,
and HLO-text emission (the exact path `make artifacts` exercises)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import manifest_line, to_hlo_text
from compile.kernels.viterbi_pallas import KernelConfig, uniform_pm0
from compile.model import decode_batch, decode_batch_ref, example_inputs

from .test_kernel import encode_frames


SMALL = KernelConfig(k=5, generators=(0o23, 0o35), f=32, v1=8, v2=12, f0=8)


def test_example_input_shapes():
    llr, pm0 = example_inputs(SMALL, 3)
    assert llr.shape == (3, SMALL.L, 2) and llr.dtype == jnp.float32
    assert pm0.shape == (3, 16) and pm0.dtype == jnp.float32


def test_decode_batch_output_shape():
    fn = decode_batch(SMALL, 2)
    rng = np.random.default_rng(0)
    frames, pm0, _ = encode_frames(SMALL, 2, rng, ebn0_db=4.0)
    (out,) = fn(frames, pm0)
    assert out.shape == (2, SMALL.f)
    assert out.dtype == jnp.int32
    assert set(np.unique(np.asarray(out))) <= {0, 1}


def test_unified_vs_ref_graph_serial_mode():
    # With f0 = f the unified kernel is a serial-traceback decoder and
    # must match the pure-jnp baseline graph bit-for-bit.
    cfg = KernelConfig(k=5, generators=(0o23, 0o35), f=32, v1=8, v2=12, f0=32)
    rng = np.random.default_rng(1)
    frames, pm0, _ = encode_frames(cfg, 2, rng, ebn0_db=2.0)
    (a,) = decode_batch(cfg, 2)(frames, pm0)
    (b,) = decode_batch_ref(cfg, 2)(frames, pm0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ref_graph_recovers_noiseless():
    rng = np.random.default_rng(2)
    frames, pm0, bits = encode_frames(SMALL, 3, rng)
    (out,) = decode_batch_ref(SMALL, 3)(frames, pm0)
    np.testing.assert_array_equal(np.asarray(out).reshape(-1), bits)


@pytest.mark.parametrize("kind", ["unified", "ref"])
def test_hlo_text_lowering(kind):
    fn = decode_batch(SMALL, 2) if kind == "unified" else decode_batch_ref(SMALL, 2)
    lowered = jax.jit(fn).lower(*example_inputs(SMALL, 2))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,52,2]" in text            # llr input shape
    assert "s32[2,32]" in text              # bits output shape
    # No Mosaic custom-calls may survive: interpret-mode lowering only.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_hlo_executes_on_cpu_backend():
    # Round-trip sanity: the lowered module compiled by the local CPU
    # backend must reproduce the eager kernel output.
    fn = decode_batch(SMALL, 2)
    rng = np.random.default_rng(3)
    frames, pm0, _ = encode_frames(SMALL, 2, rng, ebn0_db=3.0)
    eager = np.asarray(fn(frames, pm0)[0])
    compiled = jax.jit(fn).lower(frames, pm0).compile()
    jitted = np.asarray(compiled(frames, pm0)[0])
    np.testing.assert_array_equal(eager, jitted)


def test_manifest_line_format():
    line = manifest_line("x", SMALL, 2, "unified")
    parts = line.split()
    assert parts == ["x", "unified", "2", "52", "32", "8", "12", "8", "5", "2", "23", "35"]


def test_pm0_pinning_changes_first_frame_only():
    rng = np.random.default_rng(4)
    frames, _, _ = encode_frames(SMALL, 2, rng, ebn0_db=0.0)
    fn = decode_batch(SMALL, 2)
    pinned = uniform_pm0(2, 16, pin_first=True)
    free = uniform_pm0(2, 16, pin_first=False)
    (a,) = fn(frames, pinned)
    (b,) = fn(frames, free)
    # Frame 1 (not pinned in either) must be identical.
    np.testing.assert_array_equal(np.asarray(a)[1], np.asarray(b)[1])
