"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

The kernel must match ref.py bit-for-bit (same ACS order, same
tie-breaking), recover noiseless messages exactly, and track the ref
on noisy frames across a hypothesis sweep of geometries and SNRs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    awgn_llrs,
    decode_frame_parallel_tb_ref,
    decode_frame_ref,
    forward_ref,
)
from compile.kernels.trellis import CodeSpec, Trellis
from compile.kernels.viterbi_pallas import (
    KernelConfig,
    make_unified_decoder,
    uniform_pm0,
)


def encode_frames(cfg: KernelConfig, batch: int, rng: np.random.Generator,
                  ebn0_db=None):
    """Build (llr_frames, pm0, true_bits) for `batch` consecutive frames
    of a random stream (zero-padded at the head for v1 and at the tail
    for v2, exactly as the rust chunker does)."""
    trellis = Trellis(cfg.spec)
    n = batch * cfg.f
    bits = rng.integers(0, 2, n)
    coded = trellis.encode(bits, terminate=False)  # (n*beta,)
    if ebn0_db is None:
        llr_flat = (1.0 - 2.0 * coded.astype(np.float32)) * 4.0
    else:
        llr_flat = awgn_llrs(coded, ebn0_db, 0.5, rng)
    llr = llr_flat.reshape(n, cfg.spec.beta)
    pad_l = np.zeros((cfg.v1, cfg.spec.beta), np.float32)
    pad_r = np.zeros((cfg.v2, cfg.spec.beta), np.float32)
    padded = np.concatenate([pad_l, llr, pad_r])
    frames = np.stack(
        [padded[i * cfg.f : i * cfg.f + cfg.L] for i in range(batch)]
    )
    pm0 = uniform_pm0(batch, cfg.spec.num_states, pin_first=True)
    return jnp.asarray(frames), pm0, bits


class TestNoiseless:
    def test_recovers_message_exactly(self):
        cfg = KernelConfig(f=64, v1=8, v2=16, f0=16)
        rng = np.random.default_rng(1)
        frames, pm0, bits = encode_frames(cfg, 4, rng)
        dec = make_unified_decoder(cfg, 4)
        out = np.asarray(dec(frames, pm0)).reshape(-1)
        np.testing.assert_array_equal(out, bits)

    def test_serial_mode_recovers(self):
        cfg = KernelConfig(f=64, v1=8, v2=16, f0=64)  # f0=f → serial tb
        rng = np.random.default_rng(2)
        frames, pm0, bits = encode_frames(cfg, 3, rng)
        dec = make_unified_decoder(cfg, 3)
        out = np.asarray(dec(frames, pm0)).reshape(-1)
        np.testing.assert_array_equal(out, bits)

    def test_k5_code(self):
        cfg = KernelConfig(k=5, generators=(0o23, 0o35), f=32, v1=8, v2=12, f0=8)
        rng = np.random.default_rng(3)
        frames, pm0, bits = encode_frames(cfg, 2, rng)
        dec = make_unified_decoder(cfg, 2)
        out = np.asarray(dec(frames, pm0)).reshape(-1)
        np.testing.assert_array_equal(out, bits)


class TestKernelVsRef:
    def _compare(self, cfg: KernelConfig, batch: int, seed: int, ebn0_db: float):
        rng = np.random.default_rng(seed)
        frames, pm0, _ = encode_frames(cfg, batch, rng, ebn0_db=ebn0_db)
        trellis = Trellis(cfg.spec)
        dec = make_unified_decoder(cfg, batch)
        out = np.asarray(dec(frames, pm0))
        for b in range(batch):
            ss = 0 if b == 0 else None
            ref = decode_frame_parallel_tb_ref(
                trellis, frames[b], cfg.v1, cfg.f, min(cfg.f0, cfg.f), cfg.v2,
                start_state=ss,
            )
            np.testing.assert_array_equal(
                out[b], np.asarray(ref), err_msg=f"frame {b}"
            )

    def test_bit_exact_noisy_parallel_tb(self):
        self._compare(KernelConfig(f=64, v1=8, v2=20, f0=16), 4, 10, 2.0)

    def test_bit_exact_noisy_serial(self):
        self._compare(KernelConfig(f=48, v1=8, v2=16, f0=48), 3, 11, 1.5)

    def test_bit_exact_very_noisy(self):
        self._compare(KernelConfig(f=32, v1=4, v2=12, f0=8), 2, 12, -2.0)

    @settings(max_examples=12, deadline=None)
    @given(
        f=st.sampled_from([16, 32, 48]),
        v1=st.sampled_from([0, 4, 12]),
        v2=st.sampled_from([4, 12, 20]),
        f0=st.sampled_from([4, 8, 16, 999]),
        ebn0=st.sampled_from([-1.0, 2.0, 6.0]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, f, v1, v2, f0, ebn0, seed):
        cfg = KernelConfig(f=f, v1=v1, v2=v2, f0=f0)
        self._compare(cfg, 2, seed, ebn0)


class TestForwardInternals:
    def test_pinned_start_matches_ref(self):
        cfg = KernelConfig(f=32, v1=0, v2=8, f0=8)
        rng = np.random.default_rng(20)
        frames, _, _ = encode_frames(cfg, 1, rng, ebn0_db=3.0)
        trellis = Trellis(cfg.spec)
        # Pinned: ref with start_state=0 equals kernel fed the pinned row.
        dec = make_unified_decoder(cfg, 1)
        pm0 = uniform_pm0(1, cfg.spec.num_states, pin_first=True)
        out = np.asarray(dec(frames, pm0))[0]
        ref = decode_frame_parallel_tb_ref(
            trellis, frames[0], cfg.v1, cfg.f, cfg.f0, cfg.v2, start_state=0
        )
        np.testing.assert_array_equal(out, np.asarray(ref))

    def test_argmax_trail_matches_true_path_noiseless(self):
        cfg = KernelConfig(f=32, v1=0, v2=0, f0=32)
        rng = np.random.default_rng(21)
        trellis = Trellis(cfg.spec)
        bits = rng.integers(0, 2, cfg.f)
        coded = trellis.encode(bits, terminate=False)
        llr = ((1.0 - 2.0 * coded.astype(np.float32)) * 4.0).reshape(-1, 2)
        _, _, trail = forward_ref(trellis, jnp.asarray(llr), start_state=0)
        state = 0
        for t, b in enumerate(bits):
            state = int(trellis.next[state, b])
            assert int(trail[t]) == state


class TestVmemModel:
    def test_footprint_fields(self):
        cfg = KernelConfig()
        v = cfg.vmem_bytes()
        assert v["decisions_bitpacked"] * 32 == v["decisions_int32"]
        assert v["pm"] == 2 * 64 * 4
        # Whole working set at the paper's operating point stays far
        # under one TPU core's VMEM (~16 MiB).
        assert sum(v.values()) < 16 * 2**20


class TestSerialRefParity:
    def test_parallel_ref_with_huge_f0_equals_serial_ref(self):
        cfg = KernelConfig(f=48, v1=8, v2=16)
        rng = np.random.default_rng(30)
        frames, _, _ = encode_frames(cfg, 1, rng, ebn0_db=2.0)
        trellis = Trellis(cfg.spec)
        a = decode_frame_parallel_tb_ref(
            trellis, frames[0], cfg.v1, cfg.f, 10_000, cfg.v2, start_state=0
        )
        b = decode_frame_ref(trellis, frames[0], start_state=0)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)[cfg.v1 : cfg.v1 + cfg.f]
        )
