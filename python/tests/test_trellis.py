"""Tests for the build-time trellis tables (parity with rust code/trellis)."""

import numpy as np
import pytest

from compile.kernels.trellis import CodeSpec, Trellis, branch_metric_table


@pytest.fixture(scope="module")
def k7():
    return Trellis(CodeSpec.standard_k7())


def test_spec_validation():
    with pytest.raises(ValueError):
        CodeSpec(5, (0o171, 0o133))  # polys wider than k
    with pytest.raises(ValueError):
        CodeSpec(7, (0o171,))  # single generator


def test_state_graph_consistency(k7):
    S = k7.spec.num_states
    for i in range(S):
        for b in range(2):
            j = int(k7.next[i, b])
            assert 0 <= j < S
            d = list(k7.prev[j]).index(i)
            assert k7.prev_output[j, d] == k7.output[i, b]
            assert (j >> (k7.spec.k - 2)) == b


def test_known_first_transition(k7):
    # From state 0 input 1: next = 0b100000, outputs = MSBs of both polys.
    assert k7.next[0, 1] == 0b100000
    assert k7.output[0, 1] == 0b11
    assert k7.next[0, 0] == 0 and k7.output[0, 0] == 0


def test_impulse_response_reads_generators(k7):
    outs = []
    state = 0
    for b in [1, 0, 0, 0, 0, 0, 0]:
        outs.append(int(k7.output[state, b]))
        state = int(k7.next[state, b])
    for gi, g in enumerate(k7.spec.generators):
        bits = [(o >> gi) & 1 for o in outs]
        expect = [(g >> s) & 1 for s in range(k7.spec.k - 1, -1, -1)]
        assert bits == expect


def test_complement_pairs(k7):
    full = (1 << k7.spec.beta) - 1
    assert ((k7.output[:, 0] ^ k7.output[:, 1]) == full).all()


def test_encode_known_vector(k7):
    coded = k7.spec and k7.encode(np.array([1, 0, 0, 0, 0, 0, 0]), terminate=False)
    o0 = coded[0::2].tolist()
    o1 = coded[1::2].tolist()
    assert o0 == [1, 1, 1, 1, 0, 0, 1]   # 171 octal
    assert o1 == [1, 0, 1, 1, 0, 1, 1]   # 133 octal


def test_encode_terminates_at_zero(k7):
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2, 50)
    coded = k7.encode(bits, terminate=True)
    assert len(coded) == (50 + 6) * 2
    # all-zero message encodes to zeros
    assert (k7.encode(np.zeros(10, dtype=int)) == 0).all()


def test_branch_metric_table_matches_eq2():
    llr = np.array([1.5, -0.75])
    t = branch_metric_table(llr, 2)
    assert np.allclose(t, [0.75, -2.25, 2.25, -0.75])
    # complement property (paper eq. 8)
    assert np.allclose(t[[0, 1]], -t[[3, 2]])
