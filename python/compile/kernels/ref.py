"""Pure-jnp correctness oracle for the Viterbi frame kernel.

Implements the paper's Alg. 1 (forward) + Alg. 2 (backward) with
jax.lax.scan, plus the frame-level variants the Pallas kernel must
match bit-for-bit:

* ``forward_ref``      — path metrics, decisions, per-stage argmax
* ``decode_frame_ref`` — serial traceback over the whole frame
* ``decode_frame_parallel_tb_ref`` — the paper's parallel subframe
  traceback with stored-argmax start states (§IV-D)

Tie-breaking matches rust: on equal path metrics the d=0 predecessor
(state 2j) wins; argmax over states picks the lowest state index.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .gather_compat import take1, take2
from .trellis import Trellis


def _tables(trellis: Trellis):
    prev = jnp.asarray(trellis.prev)              # (S, 2)
    prev_out = jnp.asarray(trellis.prev_output)   # (S, 2)
    return prev, prev_out


def stage_metrics(llr_t: jnp.ndarray, beta: int) -> jnp.ndarray:
    """(2^beta,) branch metrics for one stage (paper eq. 2)."""
    words = jnp.arange(1 << beta)
    signs = 1.0 - 2.0 * ((words[:, None] >> jnp.arange(beta)[None, :]) & 1)
    return (signs * llr_t[None, :]).sum(axis=1).astype(jnp.float32)


def forward_ref(trellis: Trellis, llrs: jnp.ndarray, start_state=None):
    """Forward procedure over a frame.

    Args:
      llrs: (L, beta) float32 stage-major LLRs.
      start_state: int or None; None = all states start equal.

    Returns:
      decisions: (L, S) int32 in {0,1} — winning predecessor slot.
      pm_final: (S,) float32 final path metrics.
      argmax_per_stage: (L,) int32 — argmax state after every stage
        (superset of any boundary-state record the kernel keeps).
    """
    prev, prev_out = _tables(trellis)
    S = trellis.spec.num_states
    beta = trellis.spec.beta
    if start_state is None:
        pm0 = jnp.zeros((S,), dtype=jnp.float32)
    else:
        pm0 = jnp.full((S,), -jnp.inf, dtype=jnp.float32).at[start_state].set(0.0)

    def step(pm, llr_t):
        bm = stage_metrics(llr_t, beta)            # (2^beta,)
        cand = take1(pm, prev) + take1(bm, prev_out)   # (S, 2)
        # d=0 wins ties: strict greater-than for d=1.
        sel1 = cand[:, 1] > cand[:, 0]
        pm_new = jnp.where(sel1, cand[:, 1], cand[:, 0])
        dec = sel1.astype(jnp.int32)
        return pm_new, (dec, jnp.argmax(pm_new).astype(jnp.int32))

    pm_final, (decisions, argmax_per_stage) = jax.lax.scan(step, pm0, llrs)
    return decisions, pm_final, argmax_per_stage


def traceback_ref(trellis: Trellis, decisions: jnp.ndarray, start_state):
    """Serial traceback (Alg. 2) from ``start_state`` at the last stage.

    Returns (L,) int32 decoded bits (bit t = input that entered the
    state at stage t on the survivor path).
    """
    k = trellis.spec.k
    mask = trellis.spec.state_mask

    def step(state, dec_t):
        bit = state >> (k - 2)
        nxt = (2 * state + take1(dec_t, state)) & mask
        return nxt, bit

    _, bits = jax.lax.scan(
        step, jnp.asarray(start_state, jnp.int32), decisions, reverse=True
    )
    return bits.astype(jnp.int32)


def decode_frame_ref(trellis: Trellis, llrs: jnp.ndarray, start_state=None,
                     tb_state=None):
    """Whole-frame decode with serial traceback.

    tb_state: traceback start state; None = argmax of final metrics.
    Returns (L,) int32 bits.
    """
    decisions, pm, _ = forward_ref(trellis, llrs, start_state)
    start = jnp.argmax(pm).astype(jnp.int32) if tb_state is None else tb_state
    return traceback_ref(trellis, decisions, start)


def subframe_geometry(L: int, head: int, out_len: int, f0: int, v2: int):
    """Static parallel-traceback geometry (numpy, trace-time).

    Returns (starts, emit_lo, emit_hi): per-subframe traceback start
    stage (inclusive) and emit window [emit_lo, emit_hi) in frame-stage
    coordinates. Mirrors rust viterbi::unified.
    """
    n_sub = (out_len + f0 - 1) // f0
    idx = np.arange(n_sub)
    starts = np.minimum(head + (idx + 1) * f0 + v2, L) - 1
    emit_lo = head + idx * f0
    emit_hi = head + np.minimum((idx + 1) * f0, out_len)
    return starts.astype(np.int64), emit_lo.astype(np.int64), emit_hi.astype(np.int64)


def decode_frame_parallel_tb_ref(
    trellis: Trellis,
    llrs: jnp.ndarray,
    head: int,
    out_len: int,
    f0: int,
    v2: int,
    start_state=None,
    tb_state=None,
):
    """The paper's unified decode: forward + parallel subframe traceback
    with stored-argmax start states. Returns (out_len,) int32 bits.

    ``tb_state``: overrides the start state of subframes whose traceback
    begins at the frame's final stage (terminated-stream support).
    """
    L = llrs.shape[0]
    k = trellis.spec.k
    mask = trellis.spec.state_mask
    decisions, pm, argmax_per_stage = forward_ref(trellis, llrs, start_state)
    starts, emit_lo, emit_hi = subframe_geometry(L, head, out_len, f0, v2)
    n_sub = len(starts)

    final_best = jnp.argmax(pm).astype(jnp.int32)
    out = jnp.zeros((out_len,), dtype=jnp.int32)
    for s in range(n_sub):
        T = int(starts[s])
        if T == L - 1:
            st = final_best if tb_state is None else jnp.asarray(tb_state, jnp.int32)
        else:
            st = argmax_per_stage[T]
        state = st
        for t in range(T, int(emit_lo[s]) - 1, -1):
            bit = state >> (k - 2)
            if int(emit_lo[s]) <= t < int(emit_hi[s]):
                out = out.at[t - head].set(bit)
            state = (2 * state + decisions[t, state]) & mask
    return out


def awgn_llrs(coded_bits: np.ndarray, ebn0_db: float, rate: float,
              rng: np.random.Generator) -> np.ndarray:
    """Simulated receiver front end matching rust channel::awgn:
    BPSK (0→+1) + AWGN, LLR = 2y/sigma^2. Returns float32, flat
    (stage-major, lane-minor) — caller reshapes to (L, beta)."""
    sigma = float(np.sqrt(1.0 / (2.0 * rate * 10.0 ** (ebn0_db / 10.0))))
    tx = 1.0 - 2.0 * coded_bits.astype(np.float64)
    rx = tx + rng.normal(0.0, sigma, size=tx.shape)
    return (2.0 * rx / sigma**2).astype(np.float32)
