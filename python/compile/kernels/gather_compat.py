"""Gather helpers for AOT-artifact-friendly HLO.

History: during bring-up, decode artifacts returned all-zero outputs on
the deployment XLA (xla_extension 0.5.1). The root cause was *not* the
gathers but ``as_hlo_text()`` eliding large constant payloads as
``{...}``, which the 0.5.1 text parser silently accepts as empty — the
trellis tables vanished from the artifact (fix: ``as_hlo_text(True)``
in ``aot.py``; regression-guarded there and by
rust/tests/runtime_pjrt.rs).

These helpers remain in the graphs for two reasons:

* they emit the simplest possible gather form (1-D indices,
  ``index_vector_dim=1``), keeping the artifact robust against old
  backends' gather corner cases, and
* linearized gathers into a flattened operand (``take2``) lower to a
  single gather instead of a gather-of-gathers, which is also the
  layout the TPU kernel wants (one VMEM vector index stream).
"""

import jax.numpy as jnp


def take1(arr: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """``arr[idx]`` for 1-D ``arr`` and any-shape ``idx``, emitting a
    1-D-index gather."""
    flat = jnp.ravel(idx)
    return arr[flat].reshape(idx.shape)


def take2(mat: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray) -> jnp.ndarray:
    """``mat[rows, cols]`` (elementwise zip) via a linearized 1-D gather.

    ``rows`` and ``cols`` must have the same shape.
    """
    n_cols = mat.shape[1]
    flat = mat.reshape(-1)
    lin = jnp.ravel(rows) * n_cols + jnp.ravel(cols)
    return flat[lin].reshape(rows.shape)
