"""Convolutional-code trellis tables (numpy, build-time).

Conventions match the rust side exactly (rust/src/code/trellis.rs and
DESIGN.md §7): a state holds the most recent k-1 input bits, MSB =
newest; consuming bit b in state i moves to

    next(i, b) = (b << (k-2)) | (i >> 1)

and emits parity(g & r) per generator g with register r = (b << (k-1)) | i.
State j's predecessors are (2j + d) & mask for decision bit d, and the
input bit that entered j is j >> (k-2).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np


def _parity(x: int) -> int:
    return bin(x).count("1") & 1


@dataclass(frozen=True)
class CodeSpec:
    """A rate-1/beta convolutional code with constraint length k."""

    k: int
    generators: Tuple[int, ...]

    def __post_init__(self):
        if not (3 <= self.k <= 16):
            raise ValueError(f"constraint length {self.k} unsupported")
        if len(self.generators) < 2:
            raise ValueError("need at least two generators")
        for g in self.generators:
            if g == 0 or g >= (1 << self.k):
                raise ValueError(f"generator {g:o} invalid for k={self.k}")

    @property
    def beta(self) -> int:
        return len(self.generators)

    @property
    def num_states(self) -> int:
        return 1 << (self.k - 1)

    @property
    def state_mask(self) -> int:
        return self.num_states - 1

    @staticmethod
    def standard_k7() -> "CodeSpec":
        """The (2,1,7) code with generators 171, 133 (octal)."""
        return CodeSpec(7, (0o171, 0o133))

    @staticmethod
    def standard_k5() -> "CodeSpec":
        return CodeSpec(5, (0o23, 0o35))


@dataclass
class Trellis:
    """Tabulated FSM for a CodeSpec (all int32 numpy arrays)."""

    spec: CodeSpec
    next: np.ndarray = field(init=False)         # (S, 2)
    output: np.ndarray = field(init=False)       # (S, 2) branch output words
    prev: np.ndarray = field(init=False)         # (S, 2)
    prev_output: np.ndarray = field(init=False)  # (S, 2)

    def __post_init__(self):
        k, S = self.spec.k, self.spec.num_states
        mask = self.spec.state_mask
        nxt = np.zeros((S, 2), dtype=np.int32)
        out = np.zeros((S, 2), dtype=np.int32)
        for i in range(S):
            for b in range(2):
                nxt[i, b] = (b << (k - 2)) | (i >> 1)
                r = (b << (k - 1)) | i
                word = 0
                for gi, g in enumerate(self.spec.generators):
                    word |= _parity(g & r) << gi
                out[i, b] = word
        prev = np.zeros((S, 2), dtype=np.int32)
        prev_out = np.zeros((S, 2), dtype=np.int32)
        for j in range(S):
            b_in = j >> (k - 2)
            for d in range(2):
                i = (2 * j + d) & mask
                prev[j, d] = i
                prev_out[j, d] = out[i, b_in]
                assert nxt[i, b_in] == j
        self.next, self.output = nxt, out
        self.prev, self.prev_output = prev, prev_out

    def encode(self, bits: np.ndarray, terminate: bool = True) -> np.ndarray:
        """Encode a message; returns the coded bit stream
        (stage-major, lane-minor), optionally with k-1 zero tail bits."""
        bits = np.asarray(bits, dtype=np.int64)
        tail = self.spec.k - 1 if terminate else 0
        msg = np.concatenate([bits, np.zeros(tail, dtype=np.int64)])
        coded = np.zeros(len(msg) * self.spec.beta, dtype=np.int8)
        state = 0
        for t, b in enumerate(msg):
            word = int(self.output[state, b])
            for lane in range(self.spec.beta):
                coded[t * self.spec.beta + lane] = (word >> lane) & 1
            state = int(self.next[state, b])
        if terminate:
            assert state == 0
        return coded


def branch_metric_table(llr_t: np.ndarray, beta: int) -> np.ndarray:
    """The 2^beta expanded per-stage branch metrics (paper eq. 2 with the
    repetitive-pattern + complement-halving structure of §IV-B)."""
    words = np.arange(1 << beta)
    signs = 1.0 - 2.0 * ((words[:, None] >> np.arange(beta)[None, :]) & 1)
    return (signs * np.asarray(llr_t)[None, :]).sum(axis=1)
