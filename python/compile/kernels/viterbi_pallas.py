"""L1 — the unified Viterbi frame-decode Pallas kernel (paper Alg. 3).

One grid program decodes one frame: the forward procedure (branch
metrics + ACS + survivor decisions) and the backward procedure
(parallel subframe traceback, §IV-D) are fused in a single kernel, so
survivor decisions never leave on-chip memory — the TPU analogue of the
paper's shared-memory-only unified CUDA kernel (DESIGN.md §3):

* CUDA thread block ↔ grid program (one frame each);
* 2^{k-1} threads over states ↔ 64-wide vectorized ACS on the VPU;
* shared-memory survivor matrix ↔ the (L, S) decisions value that
  lives in VMEM for the lifetime of the program;
* parallel traceback threads ↔ the vectorized subframe walk.

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness (pytest vs ref.py)
plus the VMEM footprint model (rust memmodel) carry the TPU story.

Geometry is static per compiled artifact: every frame is
L = v1 + f + v2 stages and decodes the middle f. Stream edges are
handled by the rust chunker (zero-LLR padding = neutral metrics).
The initial path-metric row is an explicit input so the first frame
can pin the encoder start state (and streaming decoders can chain
frames).
"""

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .gather_compat import take1, take2
from .trellis import CodeSpec, Trellis
from .ref import subframe_geometry


@dataclass(frozen=True)
class KernelConfig:
    """Static geometry + code for one compiled kernel variant."""

    k: int = 7
    generators: Tuple[int, ...] = (0o171, 0o133)
    f: int = 256
    v1: int = 20
    v2: int = 20
    # Subframe size for the parallel traceback; f0 >= f degenerates to
    # the serial-traceback tiled kernel (method (b) baseline).
    f0: int = 32

    @property
    def spec(self) -> CodeSpec:
        return CodeSpec(self.k, self.generators)

    @property
    def L(self) -> int:
        return self.v1 + self.f + self.v2

    @property
    def name(self) -> str:
        mode = "ptb" if self.f0 < self.f else "serial"
        return (
            f"viterbi_k{self.k}_f{self.f}_v{self.v1}_{self.v2}"
            f"_{mode}{min(self.f0, self.f)}"
        )

    def vmem_bytes(self) -> dict:
        """Estimated VMEM residency per program (the §Perf model):
        decisions dominate; see rust memmodel::smem for the breakdown."""
        S = 1 << (self.k - 1)
        beta = len(self.generators)
        return {
            "llr": self.L * beta * 4,
            "decisions_bitpacked": (S + 7) // 8 * self.L,
            "decisions_int32": S * self.L * 4,  # interpret-mode layout
            "pm": 2 * S * 4,
            "argmax_trail": self.L * 4,
        }


def _traceback_maps(cfg: KernelConfig):
    """Static (numpy) maps for the vectorized parallel traceback.

    Returns:
      starts:   (n_sub,) traceback start stage per subframe (inclusive)
      max_steps: loop trip count
      w_idx, s_idx: (f,) assembly gather — decoded bit for output
        position t' comes from walk step w_idx[t'] of subframe s_idx[t'].
    """
    starts, emit_lo, emit_hi = subframe_geometry(
        cfg.L, cfg.v1, cfg.f, min(cfg.f0, cfg.f), cfg.v2
    )
    del emit_hi
    steps = starts - emit_lo + 1
    max_steps = int(steps.max())
    tprime = np.arange(cfg.f)
    s_idx = np.minimum(tprime // min(cfg.f0, cfg.f), len(starts) - 1)
    w_idx = starts[s_idx] - (cfg.v1 + tprime)
    assert (w_idx >= 0).all() and (w_idx < max_steps).all()
    return starts, max_steps, s_idx.astype(np.int32), w_idx.astype(np.int32)


def _kernel_body(
    cfg: KernelConfig,
    llr_ref,
    pm0_ref,
    prev_ref,
    prev_out_ref,
    starts_ref,
    s_idx_ref,
    w_idx_ref,
    out_ref,
):
    """The fused forward + parallel-traceback kernel for one frame.

    The trellis tables and static traceback maps arrive as (broadcast)
    kernel inputs — Pallas requires captured arrays to be explicit
    operands; they are compile-time constants in the surrounding jit.
    """
    beta = cfg.spec.beta
    k = cfg.k
    mask = cfg.spec.state_mask
    prev = prev_ref[...]             # (S, 2)
    prev_out = prev_out_ref[...]     # (S, 2)

    llr = llr_ref[0]   # (L, beta) — VMEM block
    pm0 = pm0_ref[0]   # (S,)

    # ---- forward: ACS over all states, one stage per scan step ----
    words = jnp.arange(1 << beta)
    signs = (1.0 - 2.0 * ((words[:, None] >> jnp.arange(beta)[None, :]) & 1)).astype(
        jnp.float32
    )

    def fwd(pm, llr_t):
        # 2^{beta-1} unique branch metrics, expanded (paper §IV-B):
        bm = (signs * llr_t[None, :]).sum(axis=1)
        cand = take1(pm, prev) + take1(bm, prev_out)   # (S, 2)
        sel1 = cand[:, 1] > cand[:, 0]            # ties → d=0 (rust parity)
        pm_new = jnp.where(sel1, cand[:, 1], cand[:, 0])
        return pm_new, (sel1, jnp.argmax(pm_new).astype(jnp.int32))

    _, (decisions, argmax_trail) = jax.lax.scan(fwd, pm0, llr)
    # decisions: (L, S) bool — the survivor matrix, resident on-chip.

    # ---- backward: all subframes walk in lockstep (paper Fig 5) ----
    _, max_steps, _, _ = _traceback_maps(cfg)  # static trip count
    starts = starts_ref[...]
    states0 = take1(argmax_trail, starts)         # stored-argmax policy

    def walk(carry, w):
        states = carry                            # (n_sub,)
        t = jnp.maximum(starts - w, 0)
        bits = (states >> (k - 2)).astype(jnp.int32)
        dec = take2(decisions, t, states).astype(jnp.int32)
        states = (2 * states + dec) & mask
        return states, bits

    _, walk_bits = jax.lax.scan(
        walk, states0, jnp.arange(max_steps, dtype=jnp.int32)
    )
    # walk_bits: (max_steps, n_sub) → static gather to output order.
    out_ref[0, :] = take2(walk_bits, w_idx_ref[...], s_idx_ref[...])


def make_unified_decoder(cfg: KernelConfig, batch: int, interpret: bool = True):
    """Build the batched frame decoder.

    Returns a function (llr_frames (B, L, beta) f32, pm0 (B, S) f32)
    → bits (B, f) int32. The trellis/traceback tables are bound as
    constants (they become HLO constants in the AOT artifact).
    """
    trellis = Trellis(cfg.spec)
    S = cfg.spec.num_states
    beta = cfg.spec.beta
    kernel = partial(_kernel_body, cfg)
    starts_np, _, s_idx_np, w_idx_np = _traceback_maps(cfg)
    n_sub = len(starts_np)

    prev = jnp.asarray(trellis.prev, jnp.int32)
    prev_out = jnp.asarray(trellis.prev_output, jnp.int32)
    starts = jnp.asarray(starts_np, jnp.int32)
    s_idx = jnp.asarray(s_idx_np, jnp.int32)
    w_idx = jnp.asarray(w_idx_np, jnp.int32)

    whole = lambda *shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    call = pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, cfg.L, beta), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S), lambda i: (i, 0)),
            whole(S, 2),
            whole(S, 2),
            whole(n_sub),
            whole(cfg.f),
            whole(cfg.f),
        ],
        out_specs=pl.BlockSpec((1, cfg.f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, cfg.f), jnp.int32),
        interpret=interpret,
    )

    def decode(llr_frames, pm0):
        return call(llr_frames, pm0, prev, prev_out, starts, s_idx, w_idx)

    return decode


def uniform_pm0(batch: int, S: int, pin_first: bool = False) -> jnp.ndarray:
    """Initial path-metric rows: all-equal, optionally pinning frame 0
    to encoder state 0 (stream head)."""
    pm0 = jnp.zeros((batch, S), dtype=jnp.float32)
    if pin_first and batch > 0:
        row = jnp.full((S,), -1e30, dtype=jnp.float32).at[0].set(0.0)
        pm0 = pm0.at[0].set(row)
    return pm0
