"""AOT build: lower the L2 decode graphs to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage (from the repo's python/ directory, via ``make artifacts``):

    python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per entry in :data:`CONFIGS` plus a
``manifest.txt`` the rust runtime parses. Manifest line format::

    name kind batch L f v1 v2 f0 k beta g0 g1

(kind ∈ {unified, ref}; generators in octal.)
"""

import argparse
import os
import sys

import jax

from .kernels.viterbi_pallas import KernelConfig
from .model import decode_batch, decode_batch_ref, example_inputs

# ---------------------------------------------------------------------------
# Artifact matrix.
#
# BER sweeps use the rust native engines (bit-exact vs these kernels —
# enforced by rust/tests/pjrt_vs_native.rs); artifacts cover the paper's
# operating points and the serving batch buckets (DESIGN.md §8).
# ---------------------------------------------------------------------------
K7 = dict(k=7, generators=(0o171, 0o133))

CONFIGS = [
    # (name, cfg, batch, kind)
    # Paper operating point, serial traceback (Table IV row anchor).
    ("serial_f256_v20_b8", KernelConfig(f=256, v1=20, v2=20, f0=256, **K7), 8, "unified"),
    # Paper operating point, parallel traceback (Table V / Table III
    # reliable cell: f0=32, v2=45).
    ("ptb_f256_v45_b1", KernelConfig(f=256, v1=20, v2=45, f0=32, **K7), 1, "unified"),
    ("ptb_f256_v45_b8", KernelConfig(f=256, v1=20, v2=45, f0=32, **K7), 8, "unified"),
    ("ptb_f256_v45_b32", KernelConfig(f=256, v1=20, v2=45, f0=32, **K7), 32, "unified"),
    # Small fast config for rust integration tests.
    ("test_k5_f32_b2", KernelConfig(k=5, generators=(0o23, 0o35), f=32, v1=8, v2=12, f0=8), 2, "unified"),
    # Pure-jnp baseline graph at the test shape (AOT cross-check).
    ("ref_k5_f32_b2", KernelConfig(k=5, generators=(0o23, 0o35), f=32, v1=8, v2=12, f0=8), 2, "ref"),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default elides big constant
    # payloads as "{...}", which the 0.5.1 text parser silently reads
    # as zeros — the trellis tables would vanish from the artifact.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text contains elided constants"
    return text


def build_one(name: str, cfg: KernelConfig, batch: int, kind: str, out_dir: str) -> str:
    fn = decode_batch(cfg, batch) if kind == "unified" else decode_batch_ref(cfg, batch)
    lowered = jax.jit(fn).lower(*example_inputs(cfg, batch))
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def manifest_line(name: str, cfg: KernelConfig, batch: int, kind: str) -> str:
    g = " ".join(f"{x:o}" for x in cfg.generators)
    return (
        f"{name} {kind} {batch} {cfg.L} {cfg.f} {cfg.v1} {cfg.v2} "
        f"{min(cfg.f0, cfg.f)} {cfg.k} {len(cfg.generators)} {g}"
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", help="build only configs whose name contains this")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    lines = []
    for name, cfg, batch, kind in CONFIGS:
        if args.only and args.only not in name:
            continue
        path = build_one(name, cfg, batch, kind, args.out_dir)
        size = os.path.getsize(path)
        print(f"  {name:26s} [{kind:7s}] batch={batch:<3d} L={cfg.L:<4d} -> {path} ({size//1024} KiB)")
        lines.append(manifest_line(name, cfg, batch, kind))

    mpath = os.path.join(args.out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("# name kind batch L f v1 v2 f0 k beta generators(octal)...\n")
        f.write("\n".join(lines) + "\n")
    print(f"  manifest -> {mpath} ({len(lines)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
