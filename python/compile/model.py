"""L2 — the JAX decode graph.

The "model" of this serving system is the batched frame decoder: LLR
frames in, decoded bits out, with the L1 Pallas kernel doing the work.
This module builds the jit-able functions that ``aot.py`` lowers to the
HLO artifacts the rust runtime executes; python never runs at serve
time.

Two graph variants are exported per configuration:

* ``decode_batch``        — the unified kernel (paper method (c));
* ``decode_batch_ref``    — the pure-jnp tiled baseline (method (b)),
  used for kernel-vs-ref AOT cross-checks and as the baseline engine
  artifact.
"""

import jax
import jax.numpy as jnp

from .kernels.ref import decode_frame_ref
from .kernels.trellis import Trellis
from .kernels.viterbi_pallas import KernelConfig, make_unified_decoder


def decode_batch(cfg: KernelConfig, batch: int):
    """Batched unified decode: (llr (B,L,beta) f32, pm0 (B,S) f32) →
    (bits (B,f) int32,). Tuple-wrapped for the AOT interchange."""
    kernel = make_unified_decoder(cfg, batch)

    def fn(llr_frames, pm0):
        return (kernel(llr_frames, pm0),)

    return fn


def decode_batch_ref(cfg: KernelConfig, batch: int):
    """Batched pure-jnp tiled baseline (serial traceback, method (b)).

    Same signature as :func:`decode_batch`; the traceback here is the
    whole-frame serial walk, emitting only the middle f stages.
    """
    trellis = Trellis(cfg.spec)
    del batch  # vmap handles any leading dim

    def one(llr, pm0):
        decisions, pm, _ = _forward_with_pm0(trellis, llr, pm0)
        start = jnp.argmax(pm).astype(jnp.int32)
        from .kernels.ref import traceback_ref

        bits = traceback_ref(trellis, decisions, start)
        return bits[cfg.v1 : cfg.v1 + cfg.f]

    def fn(llr_frames, pm0):
        return (jax.vmap(one)(llr_frames, pm0),)

    return fn


def _forward_with_pm0(trellis: Trellis, llrs, pm0):
    """forward_ref variant taking an explicit initial PM row (matches
    the kernel's input contract)."""
    from .kernels.ref import stage_metrics

    prev = jnp.asarray(trellis.prev)
    prev_out = jnp.asarray(trellis.prev_output)
    beta = trellis.spec.beta

    from .kernels.gather_compat import take1

    def step(pm, llr_t):
        bm = stage_metrics(llr_t, beta)
        cand = take1(pm, prev) + take1(bm, prev_out)
        sel1 = cand[:, 1] > cand[:, 0]
        pm_new = jnp.where(sel1, cand[:, 1], cand[:, 0])
        return pm_new, (sel1.astype(jnp.int32), jnp.argmax(pm_new).astype(jnp.int32))

    pm_final, (decisions, trail) = jax.lax.scan(step, pm0, llrs)
    return decisions, pm_final, trail


def example_inputs(cfg: KernelConfig, batch: int):
    """ShapeDtypeStructs for lowering."""
    S = cfg.spec.num_states
    beta = cfg.spec.beta
    return (
        jax.ShapeDtypeStruct((batch, cfg.L, beta), jnp.float32),
        jax.ShapeDtypeStruct((batch, S), jnp.float32),
    )
