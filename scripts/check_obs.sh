#!/usr/bin/env bash
# Observability gate (CI "build-test" job, obs step):
#   1. the obs unit suites — tracer ring buffer, stage-timing
#      accumulator, decayed-EWMA feedback, the metrics registry
#      (per-route latency + error-kind counters), and the planner's
#      observed-drift blend;
#   2. the traced-decode acceptance suite (2^16-stage block-parallel
#      stream -> balanced Chrome spans, nonzero ACS/traceback clocks);
#   3. a `viterbi-repro trace` run — the CLI self-validates the span
#      stream and exits nonzero on any violation — plus an independent
#      re-validation of the emitted trace.json here;
#   4. a stage-timed bench smoke: the stage_*_ns record columns must be
#      populated for the instrumented engines.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== obs: unit suites (trace / stage / ewma / metrics / planner) =="
cargo test -q --lib obs::
cargo test -q --lib coordinator::metrics
cargo test -q --lib tuner::planner

echo "== obs: traced-decode acceptance suite =="
cargo test -q --test obs_trace

echo "== obs: traced 2^16-stage blocks decode -> trace.json =="
cargo run --release --quiet -- trace --stages 65536 --engine blocks --out trace.json
test -s trace.json

python3 - trace.json <<'EOF'
import json
import sys

path = sys.argv[1]
events = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            events.append(json.loads(line))
if not events:
    print("FAIL: empty trace in", path)
    sys.exit(1)

open_spans = {}
lane_groups = 0
acs = traceback = 0.0
for ev in events:
    ph, tid = ev["ph"], ev["tid"]
    if ph == "B":
        if ev["name"] == "lane_group":
            lane_groups += 1
        open_spans.setdefault(tid, []).append(ev["name"])
    elif ph == "E":
        stack = open_spans.setdefault(tid, [])
        if not stack or stack.pop() != ev["name"]:
            print(f"FAIL: unbalanced span {ev['name']!r} on tid {tid}")
            sys.exit(1)
    elif ph == "C":
        if ev["name"] == "acs_ns":
            acs = ev["args"]["value"]
        elif ev["name"] == "traceback_ns":
            traceback = ev["args"]["value"]

leftover = {t: s for t, s in open_spans.items() if s}
if leftover:
    print("FAIL: unclosed spans:", leftover)
    sys.exit(1)
if lane_groups < 1:
    print("FAIL: no lane_group spans")
    sys.exit(1)
if acs <= 0 or traceback <= 0:
    print(f"FAIL: stage counters missing (acs={acs}, traceback={traceback})")
    sys.exit(1)
print(
    f"OK: {len(events)} events, {lane_groups} lane group(s), "
    f"acs {acs:.0f} ns, traceback {traceback:.0f} ns"
)
EOF

echo "== obs: stage-timed bench smoke (stage_*_ns columns populated) =="
cargo run --release --quiet -- bench --engines unified,blocks --frames 16 \
    --frame-lens 256 --samples 2 --warmup 1 --stage-timings --out BENCH_obs.json
test -s BENCH_obs.json

python3 - BENCH_obs.json <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))
if not records:
    print("FAIL: no bench records in", path)
    sys.exit(1)
for r in records:
    if r["stage_acs_ns"] <= 0 or r["stage_traceback_ns"] <= 0:
        print(
            f"FAIL: {r['engine']}: stage columns empty "
            f"(acs={r['stage_acs_ns']}, tb={r['stage_traceback_ns']})"
        )
        sys.exit(1)
print("OK:", "; ".join(
    f"{r['engine']} acs {r['stage_acs_ns']} ns / tb {r['stage_traceback_ns']} ns"
    for r in records
))
EOF

echo "obs OK: suites green; trace.json balanced with lane_group spans; stage columns live"
