#!/usr/bin/env bash
# Tropical-GEMM gate (CI "build-test" job, tgemm step):
#   1. the semiring property suite — min-plus associativity/identity,
#      blocked-vs-naive kernel equivalence, stage-batch composition —
#      and the parity suite: exhaustive K=3/5/7 bit-exactness against
#      the whole-stream `unified` reference plus randomized K=9 parity
#      and blocking-sweep output invariance;
#   2. a bench smoke at K=9 (the constraint length the planner routes
#      to tgemm): the stage-batched, state-tiled min-plus sweep must
#      beat the serial `unified` walk outright at 256 states, and stay
#      within noise of it at K=7 (64 states, where the slab buys less);
#   3. the committed bench/records/BENCH_pr10.jsonl must parse
#      alongside the baseline: `bench diff` in trend mode over the two
#      committed record sets, failing on any beyond-noise drift.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tgemm: semiring property + parity suites =="
cargo test -q --test tgemm_props
cargo test -q --test tgemm_parity

echo "== tgemm: K=9 bench smoke (2^16 stages, 256 states) =="
cargo run --release -- bench --k 9 --engines tgemm,unified --frames 64 \
    --frame-lens 1024 --samples 3 --warmup 1 --out BENCH_tgemm_k9.json
test -s BENCH_tgemm_k9.json

python3 - BENCH_tgemm_k9.json <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))

by_engine = {r["engine"]: r for r in records if r["k"] == 9}
for name in ("tgemm", "unified"):
    if name not in by_engine:
        print(f"FAIL: no `{name}` record at K=9 in", path)
        sys.exit(1)

tgemm_mbps = by_engine["tgemm"]["median_mbps"]
unified_mbps = by_engine["unified"]["median_mbps"]
ratio = tgemm_mbps / unified_mbps if unified_mbps > 0 else float("inf")
verdict = "OK" if tgemm_mbps > unified_mbps else "FAIL"
print(
    f"{verdict}: K=9 65536-stage stream: tgemm {tgemm_mbps:.1f} Mb/s "
    f"vs unified {unified_mbps:.1f} Mb/s ({ratio:.2f}x)"
)
sys.exit(0 if tgemm_mbps > unified_mbps else 1)
EOF

echo "== tgemm: K=7 bench smoke (64 states, parity-with-noise check) =="
cargo run --release -- bench --k 7 --engines tgemm,unified --frames 64 \
    --frame-lens 1024 --samples 3 --warmup 1 --out BENCH_tgemm_k7.json
test -s BENCH_tgemm_k7.json

python3 - BENCH_tgemm_k7.json <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))

by_engine = {r["engine"]: r for r in records if r["k"] == 7}
for name in ("tgemm", "unified"):
    if name not in by_engine:
        print(f"FAIL: no `{name}` record at K=7 in", path)
        sys.exit(1)

tgemm_mbps = by_engine["tgemm"]["median_mbps"]
unified_mbps = by_engine["unified"]["median_mbps"]
ratio = tgemm_mbps / unified_mbps if unified_mbps > 0 else 0.0
# At 64 states the slab amortizes little; tgemm only has to stay
# within noise of the serial reference, not beat it.
verdict = "OK" if ratio >= 0.85 else "FAIL"
print(
    f"{verdict}: K=7 65536-stage stream: tgemm {tgemm_mbps:.1f} Mb/s "
    f"vs unified {unified_mbps:.1f} Mb/s ({ratio:.2f}x, floor 0.85x)"
)
sys.exit(0 if ratio >= 0.85 else 1)
EOF

echo "== tgemm: committed record trend (baseline -> pr10) =="
# Explicit file paths, not the records directory: the bench-diff step
# refreshes BENCH_current.jsonl in the same directory on CI runners,
# and this leg must stay deterministic over committed records only.
cargo run --release --quiet -- bench diff bench/records/BENCH_pr10.jsonl \
    --against bench/records/BENCH_baseline.jsonl

echo "tgemm OK: semiring laws + parity green; min-plus sweep wins at K=9; records parse"
