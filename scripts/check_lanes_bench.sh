#!/usr/bin/env bash
# CI gate for the lane-batched engines (CI "build-test" job, lanes
# bench smoke step): the BENCH_lanes.json emitted by
#   viterbi-repro bench --engines scalar,lanes,lanes-mt ...
# must contain a `lanes` record with a recorded lane_width, and the
# lanes median throughput must not be below `scalar` on the same frame
# geometry — lane batching that loses to the whole-stream reference
# means the SIMD path has regressed into scalar dispatch.
set -euo pipefail

file="${1:-BENCH_lanes.json}"
if [ ! -s "$file" ]; then
    echo "FAIL: $file missing or empty"
    exit 1
fi

python3 - "$file" <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))

by_engine = {}
for r in records:
    by_engine.setdefault(r["engine"], []).append(r)

if "lanes" not in by_engine:
    print("FAIL: no `lanes` record in", path)
    sys.exit(1)

fail = False
for lanes_rec in by_engine["lanes"]:
    if lanes_rec.get("lane_width", 0) < 2:
        print("FAIL: lanes record has lane_width", lanes_rec.get("lane_width"))
        fail = True
    peers = [
        s for s in by_engine.get("scalar", [])
        if s["frame_len"] == lanes_rec["frame_len"]
        and s["batch_frames"] == lanes_rec["batch_frames"]
    ]
    if not peers:
        print("FAIL: no scalar record on frame_len", lanes_rec["frame_len"])
        fail = True
        continue
    scalar_mbps = peers[0]["median_mbps"]
    lanes_mbps = lanes_rec["median_mbps"]
    ratio = lanes_mbps / scalar_mbps if scalar_mbps > 0 else float("inf")
    verdict = "OK" if lanes_mbps >= scalar_mbps else "FAIL"
    print(
        f"{verdict}: f={lanes_rec['frame_len']} lanes {lanes_mbps:.1f} Mb/s "
        f"vs scalar {scalar_mbps:.1f} Mb/s ({ratio:.2f}x)"
    )
    if lanes_mbps < scalar_mbps:
        fail = True

sys.exit(1 if fail else 0)
EOF
echo "lanes bench OK"
