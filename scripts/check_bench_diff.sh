#!/usr/bin/env bash
# Perf-trajectory gate (CI "build-test" job, bench-diff step):
#   1. the unit + golden suites for the trajectory readers — key
#      alignment, delta classification, rank geomeans, side-by-side
#      cmp, and the lenient v1/v2-skipping record reader;
#   2. self-diff sanity: the committed baseline against itself must be
#      regression-free by construction (exit 0);
#   3. exit-contract check: a synthetically slowed copy of the
#      baseline MUST make `bench diff` exit 2 — proves the gate has
#      teeth before we trust leg 4;
#   4. the live gate: re-run the baseline scenario on this runner,
#      diff against bench/records/BENCH_baseline.jsonl normalized by
#      the scalar reference engine (cancels raw machine speed) under a
#      generous noise threshold, fail on any regression, and refresh
#      bench/records/BENCH_current.jsonl so each PR carries the record
#      it was judged with.
# BENCH_DIFF_THRESHOLD overrides the live-gate noise threshold (%).
# BENCH_DIFF_SKIP_RERUN=1 runs only the hermetic legs 1-3.
set -euo pipefail
cd "$(dirname "$0")/.."

BASELINE=bench/records/BENCH_baseline.jsonl
CURRENT=bench/records/BENCH_current.jsonl
THRESHOLD="${BENCH_DIFF_THRESHOLD:-40}"

echo "== bench-diff: unit + golden suites (analysis / compare / reader) =="
cargo test -q --lib bench::
cargo test -q --test bench_analysis

echo "== bench-diff: baseline vs itself is clean =="
cargo run --release --quiet -- bench diff "$BASELINE" "$BASELINE"

echo "== bench-diff: synthetic regression must exit 2 =="
python3 - "$BASELINE" /tmp/BENCH_regressed.jsonl <<'EOF'
import json
import sys

src, dst = sys.argv[1], sys.argv[2]
with open(src) as f, open(dst, "w") as g:
    for line in f:
        line = line.strip()
        if not line:
            continue
        r = json.loads(line)
        if r["engine"] == "lanes":
            for k in ("median_mbps", "mean_mbps", "max_mbps"):
                r[k] = round(r[k] * 0.5, 3)
        g.write(json.dumps(r) + "\n")
EOF
rc=0
cargo run --release --quiet -- bench diff "$BASELINE" /tmp/BENCH_regressed.jsonl || rc=$?
if [ "$rc" -ne 2 ]; then
    echo "FAIL: halved lanes throughput exited $rc, want 2"
    exit 1
fi
echo "OK: regression detected (exit 2)"

if [ "${BENCH_DIFF_SKIP_RERUN:-0}" = "1" ]; then
    echo "bench-diff OK (hermetic legs only; rerun skipped)"
    exit 0
fi

echo "== bench-diff: live gate (normalized by scalar, noise +/-${THRESHOLD}%) =="
# parallel is excluded from the rerun: its throughput tracks the
# runner's core count, which normalizing by the single-threaded scalar
# engine cannot cancel. Its baseline cell just reports as removed.
cargo run --release -- bench --engines scalar,unified,lanes,blocks,streaming \
    --frames 64 --frame-lens 256 --samples 5 --warmup 2 --out "$CURRENT"
test -s "$CURRENT"
cargo run --release --quiet -- bench diff "$BASELINE" "$CURRENT" \
    --normalize scalar --threshold "$THRESHOLD"

echo "bench-diff OK: no regression beyond ${THRESHOLD}% vs $BASELINE; refreshed $CURRENT"
