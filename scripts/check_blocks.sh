#!/usr/bin/env bash
# Block-parallel single-stream gate (CI "build-test" job, blocks step):
#   1. the blocks correctness suites — bit-exactness against the
#      whole-stream reference at the calibrated overlap depth, output
#      invariance across block counts, and the coordinator's
#      block-parallel vs sequential-chunk reassembly equality;
#   2. a truncation-depth characterization at 3 dB — `ber --blocks`
#      exits nonzero unless the overlap-boundary artifact count decays
#      at least 5x from a (K-1)-stage overlap to the calibrated
#      5·(K-1) depth, which must itself be negligible;
#   3. a bench smoke on one 2^16-stage stream (1024 × 64) — the whole
#      point of the engine: decoding a single long stream block-parallel
#      must beat the serial whole-stream `unified` walk.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== blocks: parity + planner + reassembly suites =="
cargo test -q --test blocks_parity
cargo test -q --test coordinator_props block_parallel_matches_sequential_chunk_reassembly

echo "== blocks: truncation-depth sweep (3 dB, overlap m·(K-1), m=1..5) =="
cargo run --release --quiet -- ber --blocks --ebn0 3.0 --bits 400000

echo "== blocks: single-stream bench smoke (2^16 stages) =="
cargo run --release -- bench --engines blocks,unified --frames 64 \
    --frame-lens 1024 --samples 3 --warmup 1 --out BENCH_blocks.json
test -s BENCH_blocks.json

python3 - BENCH_blocks.json <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))

by_engine = {r["engine"]: r for r in records if r["frame_len"] == 1024}
for name in ("blocks", "unified"):
    if name not in by_engine:
        print(f"FAIL: no `{name}` record at frame_len 1024 in", path)
        sys.exit(1)

blocks_mbps = by_engine["blocks"]["median_mbps"]
unified_mbps = by_engine["unified"]["median_mbps"]
ratio = blocks_mbps / unified_mbps if unified_mbps > 0 else float("inf")
verdict = "OK" if blocks_mbps > unified_mbps else "FAIL"
print(
    f"{verdict}: 65536-stage stream: blocks {blocks_mbps:.1f} Mb/s "
    f"vs unified {unified_mbps:.1f} Mb/s ({ratio:.2f}x)"
)
sys.exit(0 if blocks_mbps > unified_mbps else 1)
EOF

echo "blocks OK: parity green; artifacts decay with depth; block-parallel beats the serial walk"
