#!/usr/bin/env bash
# Docs consistency gate (CI "docs" job): every repo path referenced by
# the top-level docs must exist. Two reference forms are checked:
#   1. inline-backtick paths rooted at rust/, python/, examples/,
#      scripts/ or .github/  (e.g. `rust/src/bench/measurement.rs`)
#   2. relative markdown links  (e.g. [DESIGN.md](DESIGN.md))
# Paths inside fenced code blocks are intentionally not parsed; quote
# a path in backticks or a link if it must be kept alive.
set -euo pipefail
cd "$(dirname "$0")/.."

docs=(README.md DESIGN.md EXPERIMENTS.md BENCHMARKS.md)
fail=0

for d in "${docs[@]}"; do
    if [ ! -f "$d" ]; then
        echo "MISSING DOC: $d"
        fail=1
        continue
    fi
    refs=$(grep -o '`[^`]*`' "$d" | tr -d '`' \
        | grep -E '^(rust/|python/|examples/|scripts/|\.github/)' || true)
    links=$(grep -oE '\]\([^)]+\)' "$d" | sed -E 's/^\]\(//; s/\)$//' \
        | grep -vE '^(https?:|#|mailto:)' || true)
    for ref in $refs $links; do
        ref="${ref%%#*}"
        [ -z "$ref" ] && continue
        if [ ! -e "$ref" ]; then
            echo "$d: stale reference: $ref"
            fail=1
        fi
    done
done

# The engine-API contract must stay documented: DESIGN.md needs the
# request/response section (with all three types named) and the README
# engine table needs its soft-output column.
if ! grep -qE '^## .*[Ee]ngine API' DESIGN.md; then
    echo "DESIGN.md: missing the engine API section heading"
    fail=1
fi
for ty in DecodeRequest DecodeOutput DecodeError SOVA; do
    if ! grep -q "$ty" DESIGN.md; then
        echo "DESIGN.md: engine API section must mention $ty"
        fail=1
    fi
done
if ! grep -q 'Soft output' README.md; then
    echo "README.md: engine table is missing the soft-output column"
    fail=1
fi

# The tail-biting/WAVA subsystem must stay documented: DESIGN.md needs
# the circular-trellis section and the README engine table its
# tail-biting column.
if ! grep -qE '^## .*[Tt]ail-biting' DESIGN.md; then
    echo "DESIGN.md: missing the tail-biting/WAVA section heading"
    fail=1
fi
for ty in WAVA TailBiting UnsupportedStreamEnd; do
    if ! grep -q "$ty" DESIGN.md; then
        echo "DESIGN.md: tail-biting section must mention $ty"
        fail=1
    fi
done
if ! grep -q 'Tail-biting' README.md; then
    echo "README.md: engine table is missing the tail-biting column"
    fail=1
fi

# The perf-trajectory tooling must stay documented: BENCHMARKS.md
# needs the trajectory section (diff/rank/cmp + the CI gate) and the
# README the subcommand trio.
if ! grep -q 'bench diff' BENCHMARKS.md; then
    echo "BENCHMARKS.md: missing the bench diff trajectory documentation"
    fail=1
fi
for ref in 'bench rank' 'bench cmp' 'check_bench_diff' 'BENCH_baseline.jsonl'; do
    if ! grep -q "$ref" BENCHMARKS.md; then
        echo "BENCHMARKS.md: trajectory section must mention $ref"
        fail=1
    fi
done
if ! grep -q 'bench diff' README.md; then
    echo "README.md: missing the bench diff/rank/cmp subcommands"
    fail=1
fi
if ! grep -q 'bench diff' EXPERIMENTS.md; then
    echo "EXPERIMENTS.md: missing the worked bench diff example"
    fail=1
fi

if [ "$fail" -eq 0 ]; then
    echo "docs OK: all referenced paths exist and the engine API is documented"
fi
exit "$fail"
