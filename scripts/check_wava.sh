#!/usr/bin/env bash
# Tail-biting / WAVA gate (CI "wava" step):
#   1. the wava correctness suites — exhaustive brute-force ML parity
#      on short blocks (K=3/5/7), circular-shift equivariance, and the
#      one-iteration ≡ best-state-truncated property;
#   2. a BER smoke at 3 dB — `ber --tail-biting` exits nonzero unless
#      the wrap-around decoder strictly beats a one-iteration truncated
#      decode of the same tail-biting frames AND the median wrap
#      iteration count stays ≤ 3 (the throughput-relevant bound: every
#      extra wrap is a full re-decode of the frame).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== wava: brute-force ML parity + property suites =="
cargo test -q --test wava_parity

echo "== wava: tail-biting BER smoke (3 dB, 128-bit control blocks) =="
cargo run --release --quiet -- ber --tail-biting --ebn0 3.0 --bits 600000 --block 128

echo "wava OK: ML parity green; wava beats truncated at 3 dB within the iteration bound"
