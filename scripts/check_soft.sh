#!/usr/bin/env bash
# Soft-output smoke gate (CI "soft-smoke" step): run the SOVA-vs-hard
# confidence-split BER check on a tiny grid — both soft-capable engine
# families at two Eb/N0 points. `ber --soft` exits nonzero when the
# high-confidence half of the bits does not show a strictly lower BER
# than the low-confidence half, so this script only orchestrates the
# grid. Keep the bit budgets small: this is a smoke test, not a sweep.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=(cargo run --release --quiet --)

for engine in scalar ptb; do
    for ebn0 in 2.5 3.0; do
        echo "== soft-smoke: engine=$engine ebn0=$ebn0 =="
        "${BIN[@]}" ber --soft --engine "$engine" --ebn0 "$ebn0" --bits 600000
    done
done

echo "soft-smoke OK: SOVA reliabilities separate errors on the whole grid"
