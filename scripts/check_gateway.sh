#!/usr/bin/env bash
# Serve-gateway gate (CI "build-test" job, gateway step):
#   1. wire-protocol + shard-router unit suites — viterbi-wire/1
#      round-trips and typed rejection of malformed frames (bad magic,
#      truncated payloads, corrupt counts, trailing bytes);
#   2. the loopback end-to-end suite — bit-exact equality against the
#      in-process coordinator across shards for hard/soft output and
#      terminated/truncated/tail-biting streams, admission shedding
#      under a pipelined burst, deadline reaping, and typed refusals
#      over a real socket;
#   3. two CLI stress runs — light load must complete every request
#      with zero shed and zero hard errors; an expiring-deadline
#      overload run must shed (typed `overloaded` replies, counted on
#      both sides) while still producing zero hard errors.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== gateway: wire + router unit suites =="
cargo test -q gateway::

echo "== gateway: loopback end-to-end suite =="
cargo test -q --test gateway

echo "== gateway: stress (light load, 2 shards) =="
cargo run --release --quiet -- serve --stress --shards 2 --requests 60 \
    --connections 3 --seed 1234 | tee stress_light.out

echo "== gateway: stress (overload via expiring deadlines) =="
cargo run --release --quiet -- serve --stress --shards 2 --requests 40 \
    --connections 4 --deadline-us 1000 --batch-wait-us 50000 \
    --seed 1234 | tee stress_overload.out

python3 - stress_light.out stress_overload.out <<'EOF'
import json
import sys


def report(path):
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line.startswith("{"):
                continue
            rec = json.loads(line)
            if rec.get("schema") == "viterbi-stress/1":
                return rec
    print(f"FAIL: no viterbi-stress/1 record in {path}")
    sys.exit(1)


def check(cond, msg):
    if not cond:
        print("FAIL:", msg)
        sys.exit(1)


light = report(sys.argv[1])
over = report(sys.argv[2])

check(light["errors"] == 0, f"light load produced hard errors: {light}")
check(light["shed"] == 0, f"light load shed requests: {light}")
check(light["completed"] == light["submitted"], f"light load dropped requests: {light}")
check(light["client_p99_ns"] > 0, f"light load published no latency: {light}")
check(len(light["gateway"]["shards"]) == 2, f"expected 2 shards: {light}")
check(
    sum(s["routed"] for s in light["gateway"]["shards"]) == light["submitted"],
    f"per-shard dispatch does not cover the load: {light}",
)

check(over["errors"] == 0, f"overload run produced hard errors: {over}")
check(over["shed"] > 0, f"overload run shed nothing: {over}")
check(
    over["gateway"]["shed"] == over["shed"],
    f"client and gateway shed counts disagree: {over}",
)
print(
    f"OK: light {light['completed']}/{light['submitted']} completed "
    f"(p99 {light['client_p99_ns'] / 1e6:.2f} ms); "
    f"overload shed {over['shed']}/{over['submitted']} with zero hard errors"
)
EOF
rm -f stress_light.out stress_overload.out

echo "gateway OK: wire protocol typed; loopback bit-exact across shards; sheds under pressure, clean under light load"
