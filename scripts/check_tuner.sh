#!/usr/bin/env bash
# CI gate for the adaptive dispatcher (CI "tune-smoke" job): the
# BENCH_auto.json emitted by
#   viterbi-repro bench --engines auto,unified,parallel,lanes,lanes-mt ...
# must contain an `auto` record, and on every measured frame geometry
# the auto median throughput must not fall below the *worst* single
# engine it can dispatch to — an adaptive dispatcher that loses to its
# own worst candidate means the planner is routing pathologically (or
# dispatch overhead has exploded).
set -euo pipefail

file="${1:-BENCH_auto.json}"
if [ ! -s "$file" ]; then
    echo "FAIL: $file missing or empty"
    exit 1
fi

python3 - "$file" <<'EOF'
import json
import sys

path = sys.argv[1]
records = []
with open(path) as f:
    for line in f:
        line = line.strip()
        if line:
            records.append(json.loads(line))

by_engine = {}
for r in records:
    by_engine.setdefault(r["engine"], []).append(r)

if "auto" not in by_engine:
    print("FAIL: no `auto` record in", path)
    sys.exit(1)

# The bit-exact family the planner dispatches among
# (tuner::DISPATCH_CANDIDATES).
candidates = ["unified", "parallel", "lanes", "lanes-mt"]
fail = False
for auto_rec in by_engine["auto"]:
    peers = [
        r
        for e in candidates
        for r in by_engine.get(e, [])
        if r["frame_len"] == auto_rec["frame_len"]
        and r["batch_frames"] == auto_rec["batch_frames"]
    ]
    if not peers:
        print("FAIL: no candidate records on frame_len", auto_rec["frame_len"])
        fail = True
        continue
    worst = min(p["median_mbps"] for p in peers)
    auto_mbps = auto_rec["median_mbps"]
    verdict = "OK" if auto_mbps >= worst else "FAIL"
    print(
        f"{verdict}: f={auto_rec['frame_len']} auto {auto_mbps:.1f} Mb/s "
        f"vs worst dispatch candidate {worst:.1f} Mb/s"
    )
    if auto_mbps < worst:
        fail = True

sys.exit(1 if fail else 0)
EOF
echo "tuner bench OK"
