//! End-to-end serving driver (the repo's E2E validation run, recorded
//! in EXPERIMENTS.md §E2E).
//!
//! Loads the AOT-compiled XLA decode artifact (built by
//! `make artifacts` — python runs only there), starts the L3 decode
//! service with dynamic batching, fires a closed-loop workload of
//! noisy SDR streams at it, and reports throughput, latency
//! percentiles, batching occupancy, and end-to-end BER vs theory.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example sdr_pipeline            # PJRT backend
//! cargo run --release --example sdr_pipeline -- native  # native backend
//! ```

use std::sync::Arc;
use std::time::Instant;

use viterbi::ber::{soft_viterbi_ber, DistanceSpectrum};
use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::coordinator::{BackendSpec, BatchPolicy, DecodeServer, ServerConfig};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::bits::count_bit_errors;
use viterbi::viterbi::StreamEnd;

const EBN0_DB: f64 = 4.0;
const STREAM_BITS: usize = 8 * 1024;
const REQUESTS: usize = 96;
const CLIENTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let backend_arg = std::env::args().nth(1).unwrap_or_else(|| "pjrt".into());
    let backend = match backend_arg.as_str() {
        "pjrt" => BackendSpec::Pjrt {
            artifact: "ptb_f256_v45_b8".into(),
            artifact_dir: None,
        },
        "native" => BackendSpec::Native {
            spec: CodeSpec::standard_k7(),
            geo: FrameGeometry::new(256, 20, 45),
            f0: Some(32),
        },
        other => anyhow::bail!("unknown backend {other:?} (pjrt|native)"),
    };

    let server = Arc::new(DecodeServer::start(ServerConfig {
        backend,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(1),
        },
        high_watermark: 4096,
        low_watermark: 1024,
    })?);
    let spec = server.chunker().spec.clone();

    // Pre-generate the workload: REQUESTS noisy streams.
    println!(
        "generating {} streams of {} bits at Eb/N0 = {} dB…",
        REQUESTS, STREAM_BITS, EBN0_DB
    );
    let channel = AwgnChannel::new(EBN0_DB, spec.rate());
    let mut rng = Rng64::seeded(42);
    let mut workload = Vec::with_capacity(REQUESTS);
    for _ in 0..REQUESTS {
        let mut msg = vec![0u8; STREAM_BITS];
        rng.fill_bits(&mut msg);
        let coded = encode(&spec, &msg, Termination::Truncated);
        let rx = channel.transmit(&bpsk::modulate(&coded), &mut rng);
        let llrs = llr::llrs_from_samples(&rx, channel.sigma());
        workload.push((msg, llrs));
    }

    // Closed-loop clients: each submits its share and waits.
    println!("serving with {} concurrent clients…", CLIENTS);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    let workload = Arc::new(workload);
    for c in 0..CLIENTS {
        let server = Arc::clone(&server);
        let workload = Arc::clone(&workload);
        handles.push(std::thread::spawn(move || {
            let mut errors = 0usize;
            let mut bits = 0usize;
            let mut i = c;
            while i < workload.len() {
                let (msg, llrs) = &workload[i];
                let resp = server
                    .decode_blocking(llrs.clone(), StreamEnd::Truncated)
                    .expect("decode");
                errors += count_bit_errors(&resp.bits[..msg.len()], msg);
                bits += msg.len();
                i += CLIENTS;
            }
            (errors, bits)
        }));
    }
    let (mut total_errors, mut total_bits) = (0usize, 0usize);
    for h in handles {
        let (e, b) = h.join().expect("client thread");
        total_errors += e;
        total_bits += b;
    }
    let wall = t0.elapsed();

    let m = server.metrics();
    let ber = total_errors as f64 / total_bits as f64;
    let bound = soft_viterbi_ber(EBN0_DB, 0.5, &DistanceSpectrum::k7_171_133());
    println!("\n==== sdr_pipeline results ====");
    println!("backend:            {}", server.backend_name());
    println!("streams decoded:    {REQUESTS} ({total_bits} information bits)");
    println!(
        "wall time:          {:.2?}  ->  throughput {:.2} Mb/s",
        wall,
        total_bits as f64 / wall.as_secs_f64() / 1e6
    );
    println!(
        "request latency:    p50 {:?}  p99 {:?}",
        m.p50_latency, m.p99_latency
    );
    println!(
        "batching:           {} batches, mean occupancy {:.2}, mean exec {:?}",
        m.batches, m.mean_batch_occupancy, m.mean_batch_exec
    );
    println!(
        "end-to-end BER:     {ber:.3e}   (union bound at {EBN0_DB} dB: {bound:.3e})"
    );
    anyhow::ensure!(m.responses as usize == REQUESTS, "lost responses");
    anyhow::ensure!(
        ber < bound * 3.0 + 1e-6,
        "BER {ber} out of line with bound {bound}"
    );
    println!("sdr_pipeline OK");
    Ok(())
}
