//! BER waterfall: reproduce the verification methodology of paper §V-B
//! (Fig 8/Fig 9) — sweep Eb/N0, measure BER for the optimal decoder,
//! the tiled baseline, the parallel-traceback decoder, and hard-decision
//! mode, against the union bounds.
//!
//! ```bash
//! cargo run --release --example ber_waterfall
//! ```

use std::sync::Arc;

use viterbi::ber::{
    hard_viterbi_ber, measure_point_parallel, soft_viterbi_ber, BerConfig, DistanceSpectrum,
};
use viterbi::code::CodeSpec;
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    HardEngine, ParallelTraceback, ScalarEngine, SharedEngine, StartPolicy, TiledEngine,
    TracebackMode,
};

fn main() {
    let spec = CodeSpec::standard_k7();
    let pool = ThreadPool::with_default_parallelism();
    let cfg = BerConfig {
        block_bits: 16_384,
        target_errors: 120,
        max_bits: 1_500_000,
        seed: 0xBEEF_CAFE,
        puncture: None,
    };

    let engines: Vec<(&str, SharedEngine)> = vec![
        ("optimal (whole-stream)", Arc::new(ScalarEngine::new(spec.clone()))),
        (
            "tiled serial-tb",
            Arc::new(TiledEngine::new(
                spec.clone(),
                FrameGeometry::new(256, 20, 20),
                TracebackMode::FrameSerial,
            )),
        ),
        (
            "unified parallel-tb",
            Arc::new(TiledEngine::new(
                spec.clone(),
                FrameGeometry::new(256, 20, 45),
                TracebackMode::Parallel(ParallelTraceback::new(
                    32,
                    45,
                    StartPolicy::StoredArgmax,
                )),
            )),
        ),
        (
            "hard-decision",
            Arc::new(HardEngine::new(ScalarEngine::new(spec.clone()))),
        ),
    ];

    println!(
        "{:>8} {:>24} {:>24} {:>24} {:>24} {:>12} {:>12}",
        "Eb/N0", "optimal", "tiled", "parallel-tb", "hard", "soft-bound", "hard-bound"
    );
    let s = DistanceSpectrum::k7_171_133();
    for tenth in [20i32, 25, 30, 35, 40, 45, 50] {
        let db = tenth as f64 / 10.0;
        let mut row = format!("{db:>8.1}");
        for (_, engine) in &engines {
            let p = measure_point_parallel(&spec, Arc::clone(engine), &cfg, db, &pool);
            row += &format!(
                " {:>17.3e}({:>4})",
                p.ber,
                if p.reliable { "ok" } else { "~" }
            );
        }
        row += &format!(
            " {:>12.3e} {:>12.3e}",
            soft_viterbi_ber(db, 0.5, &s),
            hard_viterbi_ber(db, 0.5, &s)
        );
        println!("{row}");
    }
    println!("\n(soft gains ≈2 dB over hard; tiled/parallel-tb track the optimal curve)");
}
