//! Thread-scaling probe for the frame-parallel engine (§Perf):
//! decode the same stream with 1/4/16 pool threads and sequentially.
//! (This image exposes a single core — see EXPERIMENTS.md.)
use std::sync::Arc;
use viterbi::channel::Rng64;
use viterbi::code::CodeSpec;
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::threadpool::ThreadPool;
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelEngine, StreamEnd, TiledEngine, TracebackMode,
};
fn main() {
    let bits = 1usize << 20;
    let mut rng = Rng64::seeded(1);
    let llrs: Vec<f32> = (0..bits*2).map(|_| (rng.uniform() as f32 - 0.5)*8.0).collect();
    let spec = CodeSpec::standard_k7();
    let geo = FrameGeometry::new(256, 20, 20);
    for threads in [1usize, 4, 16] {
        let pool = Arc::new(ThreadPool::new(threads));
        let engine = ParallelEngine::new(TiledEngine::new(spec.clone(), geo, TracebackMode::FrameSerial), pool);
        let req = DecodeRequest::hard(&llrs, bits, StreamEnd::Truncated);
        let _ = engine.decode(&req).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..3 { std::hint::black_box(engine.decode(&req).unwrap()); }
        let dt = t0.elapsed().as_secs_f64();
        println!("threads={threads}: {:.1} Mb/s", 3.0*bits as f64/dt/1e6);
    }
    // single-thread sequential engine for reference
    let eng = TiledEngine::new(spec.clone(), geo, TracebackMode::FrameSerial);
    let t0 = std::time::Instant::now();
    let req = DecodeRequest::hard(&llrs, bits, StreamEnd::Truncated);
    std::hint::black_box(eng.decode(&req).unwrap());
    println!("sequential TiledEngine: {:.1} Mb/s", bits as f64/t0.elapsed().as_secs_f64()/1e6);
}
