//! Profiling harness for the forward-pass hot loop (§Perf): runs
//! 30k frame forwards so `perf record target/release/examples/profloop`
//! lands squarely on the ACS butterfly.
use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination, Trellis};
use viterbi::viterbi::{FrameScratch, frame::forward_frame};
fn main() {
    let spec = CodeSpec::standard_k7();
    let trellis = Trellis::new(spec.clone());
    let mut rng = Rng64::seeded(6);
    let span_len = 321usize;
    let mut msg = vec![0u8; span_len];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Truncated);
    let ch = AwgnChannel::new(3.0, 0.5);
    let rx = ch.transmit(&bpsk::modulate(&coded), &mut rng);
    let llrs = llr::llrs_from_samples(&rx, ch.sigma());
    let mut scratch = FrameScratch::new(64, span_len);
    let mut acc = 0u32;
    for _ in 0..30000 {
        acc ^= forward_frame(&trellis, &llrs, None, &[], &mut scratch);
    }
    println!("{acc}");
}
