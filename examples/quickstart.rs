//! Quickstart: encode a message, push it through a noisy channel, and
//! decode it with the paper's unified parallel-traceback decoder.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{encode, CodeSpec, Termination};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::bits::count_bit_errors;
use viterbi::viterbi::{
    DecodeRequest, Engine, ParallelTraceback, StartPolicy, StreamEnd, TiledEngine,
    TracebackMode,
};

fn main() {
    // 1. The industry-standard (2,1,7) code with generators 171, 133.
    let spec = CodeSpec::standard_k7();

    // 2. A random 10k-bit message, encoded with trellis termination.
    let mut rng = Rng64::seeded(2020);
    let mut message = vec![0u8; 10_000];
    rng.fill_bits(&mut message);
    let coded = encode(&spec, &message, Termination::Terminated);
    println!("message: {} bits -> {} coded bits", message.len(), coded.len());

    // 3. BPSK over AWGN at Eb/N0 = 3 dB, LLRs at the receiver.
    let channel = AwgnChannel::new(3.0, spec.rate());
    let received = channel.transmit(&bpsk::modulate(&coded), &mut rng);
    let llrs = llr::llrs_from_samples(&received, channel.sigma());

    // 4. Decode with the paper's configuration: frames of f=256 with
    //    overlaps v1=20 / v2=45, parallel traceback in f0=32 subframes,
    //    stored-argmax start states.
    let engine = TiledEngine::new(
        spec.clone(),
        FrameGeometry::new(256, 20, 45),
        TracebackMode::Parallel(ParallelTraceback::new(32, 45, StartPolicy::StoredArgmax)),
    );
    let stages = message.len() + (spec.k - 1) as usize;
    let output = engine
        .decode(&DecodeRequest::soft(&llrs, stages, StreamEnd::Terminated))
        .expect("well-formed request");
    let decoded = &output.bits;

    // 5. Compare — and peek at the SOVA reliabilities that came along.
    let errors = count_bit_errors(&decoded[..message.len()], &message);
    println!(
        "decoded with {}: {} bit errors out of {} (BER {:.2e})",
        engine.name(),
        errors,
        message.len(),
        errors as f64 / message.len() as f64
    );
    let soft = output.soft.as_ref().expect("soft output requested");
    let mut ranked: Vec<usize> = (0..message.len()).collect();
    ranked.sort_by(|&a, &b| soft[a].abs().partial_cmp(&soft[b].abs()).unwrap());
    println!(
        "least-confident bits (SOVA): {:?} — errors cluster here",
        &ranked[..5]
    );
    assert!(errors < 50, "unexpectedly high error count");
    println!("quickstart OK");
}
