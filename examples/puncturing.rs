//! Puncturing demo (paper §IV-E): encode at rate 1/2, puncture to 2/3
//! and 3/4 with the standard DVB patterns, transmit, de-puncture with
//! neutral LLRs, and decode — showing the rate/BER trade.
//!
//! ```bash
//! cargo run --release --example puncturing
//! ```

use viterbi::channel::{bpsk, llr, AwgnChannel, Rng64};
use viterbi::code::{
    depuncture_llrs, encode, puncture, CodeSpec, PuncturePattern, Termination,
};
use viterbi::frames::plan::FrameGeometry;
use viterbi::util::bits::count_bit_errors;
use viterbi::viterbi::{DecodeRequest, Engine, StreamEnd, TiledEngine, TracebackMode};

fn main() {
    let spec = CodeSpec::standard_k7();
    let engine = TiledEngine::new(
        spec.clone(),
        FrameGeometry::new(256, 32, 32),
        TracebackMode::FrameSerial,
    );
    let mut rng = Rng64::seeded(99);
    let n = 200_000usize;
    let ebn0_db = 3.5;

    let mut msg = vec![0u8; n];
    rng.fill_bits(&mut msg);
    let coded = encode(&spec, &msg, Termination::Terminated);
    let stages = n + (spec.k - 1) as usize;

    println!("message {n} bits, Eb/N0 {ebn0_db} dB\n");
    println!(
        "{:>6} {:>12} {:>12} {:>10}",
        "rate", "tx bits", "bit errors", "BER"
    );
    for label in ["1/2", "2/3", "3/4"] {
        let pat = PuncturePattern::by_label(label).unwrap();
        let tx_bits = puncture(&coded, 2, &pat);
        // Eb/N0 is per information bit: the channel rate follows the
        // effective (punctured) code rate.
        let ch = AwgnChannel::new(ebn0_db, pat.effective_rate());
        let rx = ch.transmit(&bpsk::modulate(&tx_bits), &mut rng);
        let rx_llrs = llr::llrs_from_samples(&rx, ch.sigma());
        let full = depuncture_llrs(&rx_llrs, 2, &pat, stages);
        let out = engine
            .decode(&DecodeRequest::hard(&full, stages, StreamEnd::Terminated))
            .expect("decode")
            .bits;
        let errors = count_bit_errors(&out[..n], &msg);
        println!(
            "{:>6} {:>12} {:>12} {:>10.2e}",
            label,
            tx_bits.len(),
            errors,
            errors as f64 / n as f64
        );
    }
    println!("\n(fewer transmitted bits ⇒ higher rate ⇒ more errors, as §IV-E describes)");
}
